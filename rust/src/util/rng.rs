//! Deterministic PRNG (SplitMix64 core) — no external `rand` dependency.
//!
//! Every stochastic component in the repo (failure injection, simulated
//! latency draws, synthetic corpus generation, property tests) draws from
//! this generator so experiments are exactly reproducible from a seed.

/// SplitMix64: tiny, fast, passes BigCrush for our purposes, and — unlike
/// `rand` — available offline. 64-bit state, 64-bit output.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E37_79B9_7F4A_7C15) }
    }

    /// Derive an independent stream (e.g. per node / per worker).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.f64() * (hi - lo)
    }

    /// Uniform integer in [0, n). n must be > 0.
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire-style rejection-free enough for simulation use.
        (self.f64() * n as f64) as u64
    }

    /// Uniform integer in [lo, hi).
    pub fn range(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(hi > lo);
        lo + self.below(hi - lo)
    }

    pub fn bool(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()
    }

    /// Normal with mean/stddev, clamped to [lo, hi].
    pub fn normal_clamped(&mut self, mean: f64, std: f64, lo: f64, hi: f64) -> f64 {
        (mean + std * self.normal()).clamp(lo, hi)
    }

    /// Exponential with the given rate (inter-arrival times of failures).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -self.f64().max(1e-300).ln() / rate
    }

    /// Pick an index according to (unnormalised) weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Choose one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        assert_ne!(Rng::new(1).next_u64(), Rng::new(2).next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(13);
        let n = 50_000;
        let m: f64 = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((m - 0.5).abs() < 0.02, "mean={m}");
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert_ne!(r.weighted(&[1.0, 0.0, 3.0]), 1);
        }
    }

    #[test]
    fn weighted_distribution_roughly_proportional() {
        let mut r = Rng::new(19);
        let mut counts = [0u32; 3];
        for _ in 0..30_000 {
            counts[r.weighted(&[1.0, 2.0, 1.0])] += 1;
        }
        let frac = counts[1] as f64 / 30_000.0;
        assert!((frac - 0.5).abs() < 0.02, "frac={frac}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(23);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(29);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
