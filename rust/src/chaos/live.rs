//! Live execution path: interpret a chaos spec against the *real*
//! in-process training plane (`coordinator::Controller` +
//! `training::worker` threads executing PJRT artifacts).
//!
//! The simulator path scales to paper-size clusters; this path trades
//! scale for realism — actual worker threads, actual collectives,
//! actual state restore. Spec faults map to scripted [`FailurePlan`]s
//! via their live hints (`rank` / `at_step` / `phase`); families with
//! no in-process equivalent (partition, spare exhaustion, straggler)
//! are rejected with a clear error so specs stay honest about what
//! each path can express.
//!
//! Requires compiled artifacts and a real `xla` backend; with the
//! vendored stub `run_live` fails fast and `scenario run` reports the
//! live plane as unavailable (DESIGN.md §7).
//!
//! Specs carrying a `netem:` section additionally run through the
//! impaired drivers (`drive_netem_*`): the identical wire protocols
//! over links injecting delay, jitter, loss, and partitions via the
//! §15 link layer, with every deadline scaled through one
//! [`Timeouts`](crate::config::Timeouts) config instead of hand-tuned
//! loopback constants.

use super::engine::AssertionOutcome;
use super::spec::{FaultFamily, NetemSpec, ScenarioSpec};
use crate::checkpoint::Snapshot;
use crate::cluster::failure::{FailureCategory, FailureKind};
use crate::comms::link::Dialer;
use crate::comms::netem::{LinkPolicy, NetemDialer, NetemMap, Partition, MAX_CHARGE};
use crate::comms::replication::{ReplicaSet, StoreSession};
use crate::comms::state_stream::{
    fetch_from_addr_via, serve_listener, EpochFence, Expect, RestoreError, StreamConfig,
};
use crate::comms::tcp_store::TcpStoreServer;
use crate::config::{ParallelismConfig, ShardId, Timeouts};
use crate::coordinator::detection::{Detection, LeaseConfig, LeaseMonitor};
use crate::coordinator::rendezvous::{rebuild_episode, EpisodeConfig, RebuildOutcome};
use crate::coordinator::restore::{
    bump_epoch, plan_shard_restore, restore_episode, synthetic_snapshot,
};
use crate::coordinator::{
    encode_leases, ControllerConfig, EpisodeCheckpoint, EpisodePhase, RankEntry,
    Ranktable, RunReport, StandbyController, K_EPISODE, K_LEASES,
};
use crate::redundancy::{
    cover_plan, reconstruct_shard, stripe_holders, RedundancyConfig, StripeDepot,
    StripeShipper, WarmSpare,
};
use crate::telemetry::{global, trace};
use crate::training::worker::{
    kind_code, spawn_heartbeat, spawn_node_heartbeat, FailurePlan, HeartbeatCfg,
    MonitorBoard, NodeAgentCfg, NodeRank, Phase,
};
use crate::training::TrainingEngine;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::{Duration, Instant};

fn parse_phase(s: &str) -> Phase {
    match s {
        "optstep" | "opt" | "optimizer" => Phase::OptStep,
        _ => Phase::FwdBwd,
    }
}

/// Expand the spec's fault timeline into scripted worker failures.
pub fn live_failure_plans(spec: &ScenarioSpec) -> Result<Vec<FailurePlan>> {
    let mut plans = Vec::new();
    for (i, f) in spec.faults.iter().enumerate() {
        let rank = |d: usize| f.rank.unwrap_or(d) % spec.live.dp.max(1);
        let step = f
            .at_step
            .with_context(|| format!("fault {i}: live path needs \"at_step\""))?;
        let kind = f.failure.unwrap_or(FailureKind::Segfault);
        let phase = parse_phase(&f.phase);
        match f.family {
            FaultFamily::Crash => {
                plans.push(FailurePlan { rank: rank(i + 1), step, phase, kind })
            }
            FaultFamily::Cascade => {
                for j in 0..f.nodes {
                    plans.push(FailurePlan {
                        rank: (rank(i + 1) + j) % spec.live.dp.max(1),
                        step: step + j as u64,
                        phase,
                        kind,
                    });
                }
            }
            FaultFamily::Flap => {
                for j in 0..f.times {
                    plans.push(FailurePlan {
                        rank: rank(i + 1),
                        step: step + j as u64 * f.period_steps.max(1),
                        phase,
                        kind,
                    });
                }
            }
            other => bail!(
                "fault {i}: {:?} has no live in-process equivalent — run this \
                 scenario on the simulator path",
                other.name()
            ),
        }
    }
    if plans.iter().any(|p| p.step >= spec.live.steps) {
        bail!(
            "live plan schedules a failure at/after the final step {} — raise \
             live.steps in the spec",
            spec.live.steps
        );
    }
    Ok(plans)
}

/// Controller configuration for the live run of a spec.
pub fn controller_config(spec: &ScenarioSpec, seed: u64) -> Result<ControllerConfig> {
    let mut cfg = ControllerConfig::flash(spec.live.dp, spec.live.steps);
    cfg.seed = seed;
    cfg.failures = live_failure_plans(spec)?;
    Ok(cfg)
}

/// Outcome of a live run: the controller's report plus the spec's
/// assertions evaluated against it.
pub struct LiveOutcome {
    pub report: RunReport,
    pub assertions: Vec<AssertionOutcome>,
}

/// Assertions meaningful on the live path, checked against the report.
pub fn evaluate_live(spec: &ScenarioSpec, report: &RunReport) -> Vec<AssertionOutcome> {
    let a = &spec.assertions;
    let mut out = Vec::new();
    let mut check = |name: &str, pass: bool, detail: String| {
        out.push(AssertionOutcome { name: name.to_string(), pass, detail });
    };
    let lost: u64 = report.recoveries.iter().map(|r| r.lost_steps).sum();
    if let Some(bound) = a.max_lost_steps {
        check("max_lost_steps", lost <= bound, format!("{lost} vs bound {bound}"));
    }
    if a.require_all_recovered {
        check(
            "require_all_recovered",
            report.final_step == spec.live.steps,
            format!("final step {} of {}", report.final_step, spec.live.steps),
        );
        check(
            "dp_replicas_bitwise_consistent",
            report.final_param_divergence == 0.0,
            format!("divergence {}", report.final_param_divergence),
        );
    }
    if let Some(min) = a.min_recoveries {
        check(
            "min_recoveries",
            report.recoveries.len() >= min,
            format!("{} vs min {min}", report.recoveries.len()),
        );
    }
    out
}

/// Drive the spec's scripted failures as *real* group-rebuild episodes
/// over a live TCP store: one epoch-fenced rendezvous per failure
/// step, with surviving ranks re-keying (O(1) messages each) and the
/// failed ranks performing full replacement joins. Exercises the
/// reconstruction protocol under chaos campaigns without requiring
/// the xla training plane.
pub fn drive_group_rebuilds(spec: &ScenarioSpec) -> Result<Vec<RebuildOutcome>> {
    let plans = live_failure_plans(spec)?;
    let dp = spec.live.dp.max(1);
    let par = ParallelismConfig::dp(dp);
    let mut table = Ranktable::new(
        (0..dp)
            .map(|rank| RankEntry {
                rank,
                node: rank,
                device: 0,
                addr: format!("127.0.0.1:{}", 29000 + rank),
            })
            .collect(),
    );
    let server = TcpStoreServer::start()?;
    // one rebuild episode per distinct failure step
    let by_step = rebuild_timeline(&plans);
    let mut epoch = 0u64;
    let mut episodes = Vec::with_capacity(by_step.len());
    for (step, mut failed) in by_step {
        failed.sort_unstable();
        let replacements: Vec<RankEntry> = failed
            .iter()
            .map(|&r| RankEntry {
                rank: r,
                node: dp + (epoch as usize + 1) * dp + r,
                device: 0,
                addr: format!("127.0.0.1:{}", 31000 + step as usize + r),
            })
            .collect();
        let out = rebuild_episode(
            &server.endpoints(),
            &table,
            &par,
            &failed,
            &replacements,
            epoch,
            &EpisodeConfig { live_survivors: dp, ..Default::default() },
        )?;
        epoch = out.epoch;
        table = out.table.clone();
        episodes.push(out);
    }
    Ok(episodes)
}

/// Collapse scripted failure plans into one rendezvous/restore episode
/// per distinct failure step (victims deduplicated, in rank order of
/// first appearance).
fn rebuild_timeline(plans: &[FailurePlan]) -> BTreeMap<u64, Vec<usize>> {
    let mut by_step: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for p in plans {
        let ranks = by_step.entry(p.step).or_default();
        if !ranks.contains(&p.rank) {
            ranks.push(p.rank);
        }
    }
    by_step
}

/// Outcome of one live restore episode driven from a chaos spec.
#[derive(Debug, Clone)]
pub struct LiveRestoreOutcome {
    /// Epoch the episode finally converged in.
    pub epoch: u64,
    /// Failure step the episode recovered (spec `at_step`).
    pub step: u64,
    pub resume_step: u64,
    /// Ranks restored (replacements for the episode's victims, plus
    /// any folded in by churn).
    pub restored: Vec<usize>,
    /// Distinct replica sources that served state.
    pub sources: Vec<usize>,
    pub bytes_moved: u64,
    pub wall_s: f64,
    /// Restore attempts aborted retryably by a mid-restore epoch bump
    /// before the episode converged.
    pub aborted_attempts: usize,
}

/// Per-rank f32 elements for the synthetic chaos model state — big
/// enough to exercise multi-chunk transfers with a small chunk size.
const CHAOS_STATE_ELEMS: usize = 30_000;

fn chaos_states(dp: usize, step: u64) -> BTreeMap<usize, Snapshot> {
    // DP replicas: identical bits on every rank by construction.
    (0..dp).map(|r| (r, synthetic_snapshot(step, CHAOS_STATE_ELEMS))).collect()
}

/// Drive the spec's scripted failures as *real* checkpoint-free
/// restore episodes over live sockets: per failure step, the victims'
/// state shards are re-streamed from surviving replicas through the
/// shard-aware planner and the epoch-fenced state-stream protocol
/// (DESIGN.md §9). Companion of [`drive_group_rebuilds`], and like it
/// requires no xla training plane — states are synthetic snapshots.
pub fn drive_restores(spec: &ScenarioSpec) -> Result<Vec<LiveRestoreOutcome>> {
    drive_restore_episodes(spec, false)
}

/// [`drive_restores`] with failure-during-restore churn: each episode
/// (except the last) is first run throttled while the *next* failure
/// strikes mid-transfer — the epoch bump must abort every in-flight
/// transfer retryably, and the replanned episode (victims folded in)
/// must still converge. This is the `restore_under_churn` scenario's
/// live assertion.
pub fn drive_restores_under_churn(spec: &ScenarioSpec) -> Result<Vec<LiveRestoreOutcome>> {
    drive_restore_episodes(spec, true)
}

fn drive_restore_episodes(
    spec: &ScenarioSpec,
    churn: bool,
) -> Result<Vec<LiveRestoreOutcome>> {
    let plans = live_failure_plans(spec)?;
    let dp = spec.live.dp.max(2);
    let par = ParallelismConfig::dp(dp);
    let server = TcpStoreServer::start()?;
    let eps = server.endpoints();

    // failure step -> distinct victim ranks (like drive_group_rebuilds)
    let timeline: Vec<(u64, Vec<usize>)> =
        rebuild_timeline(&plans).into_iter().collect();

    let mut epoch = 0u64;
    let mut episodes = Vec::with_capacity(timeline.len());
    let mut i = 0;
    while i < timeline.len() {
        let (step, mut failed) = timeline[i].clone();
        failed.sort_unstable();
        let mut aborted_attempts = 0usize;
        let fold_next = churn && i + 1 < timeline.len();

        // Fleet state when the failure strikes: replicas at `step`.
        let states = chaos_states(dp, step);
        epoch += 1;
        let fence = EpochFence::new(epoch);

        if fold_next {
            // First attempt, throttled so the next failure lands
            // mid-transfer; a watcher bumps the epoch the way the
            // controller does when detection fires during recovery.
            // The throttled transfer takes >= ~300ms of mandatory
            // per-chunk sleeps vs the 20ms watcher delay, so the bump
            // deterministically lands in flight even on loaded CI.
            let survivor_steps: Vec<(usize, u64)> = (0..dp)
                .filter(|r| !failed.contains(r))
                .map(|r| (r, step))
                .collect();
            let plan = plan_shard_restore(&par, &survivor_steps, &failed);
            let throttled = StreamConfig {
                chunk_bytes: 4 * 1024,
                throttle: Some(Duration::from_millis(10)),
                ..Default::default()
            };
            let watcher_fence = fence.clone();
            let bump_to = epoch + 1;
            let watcher_eps = eps.clone();
            let watcher = std::thread::spawn(move || {
                std::thread::sleep(Duration::from_millis(20));
                bump_epoch(&watcher_eps, &watcher_fence, bump_to)
            });
            let attempt =
                restore_episode(&eps, &plan, &states, epoch, &fence, &throttled);
            watcher
                .join()
                .map_err(|_| anyhow::anyhow!("epoch watcher panicked"))??;
            match attempt {
                Err(RestoreError::Superseded { current }) => {
                    aborted_attempts += 1;
                    epoch = current.max(epoch + 1);
                }
                Err(RestoreError::Fatal(e)) => {
                    return Err(e.context("throttled restore attempt"))
                }
                Ok(_) => bail!(
                    "mid-restore epoch bump failed to abort the in-flight episode"
                ),
            }
            // Fold the second failure's victims in and replan.
            let (_, next_failed) = timeline[i + 1].clone();
            for r in next_failed {
                if !failed.contains(&r) {
                    failed.push(r);
                }
            }
            failed.sort_unstable();
            i += 1; // the folded step is consumed by this episode
        }

        let survivor_steps: Vec<(usize, u64)> = (0..dp)
            .filter(|r| !failed.contains(r))
            .map(|r| (r, step))
            .collect();
        if survivor_steps.is_empty() {
            bail!("chaos restore episode at step {step} left no survivors");
        }
        let plan = plan_shard_restore(&par, &survivor_steps, &failed);
        if !plan.replica_feasible() {
            bail!("chaos restore episode at step {step} has unsourced shards");
        }
        let out = restore_episode(
            &eps,
            &plan,
            &states,
            epoch,
            &fence,
            &StreamConfig::default(),
        )
        .map_err(|e| anyhow::anyhow!("{e}"))?;

        // Every restored rank must be a bit-exact replica again.
        let reference = states[&plan.transfers[0].source].content_hash();
        for (rank, snap) in &out.restored {
            if snap.content_hash() != reference {
                bail!("rank {rank} diverged after restore");
            }
        }
        let mut sources: Vec<usize> =
            out.transfers.iter().map(|t| t.source).collect();
        sources.sort_unstable();
        sources.dedup();
        episodes.push(LiveRestoreOutcome {
            epoch,
            step,
            resume_step: out.resume_step,
            restored: out.restored.keys().copied().collect(),
            sources,
            bytes_moved: out.bytes_moved(),
            wall_s: out.wall_s,
            aborted_attempts,
        });
        i += 1;
    }
    Ok(episodes)
}

// ------------------------------------------------------------------
// Live detection: the full detection → rebuild → restore pipeline
// ------------------------------------------------------------------

/// How one victim presents to the wire-plane monitor.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum LiveFailureMode {
    /// Process death: beats stop (hardware kinds push their device
    /// code in the emitter's dying gasp first).
    Die,
    /// Silent hang: the worker stays alive and beating, but its step
    /// tag freezes while the group advances — detectable only via the
    /// stall-vs-median rule, never via liveness.
    Hang,
}

/// Failure step -> victims `(rank, kind, mode)` for the live driver.
type DetectionTimeline = BTreeMap<u64, Vec<(usize, FailureKind, LiveFailureMode)>>;

/// Expand the spec's faults into per-step live-detection victims.
/// Unlike [`live_failure_plans`] (worker `FailurePlan`s), stragglers
/// are *supported* here: a straggler fault maps to a silent hang, the
/// failure class this driver exists to exercise.
fn live_detection_timeline(spec: &ScenarioSpec) -> Result<DetectionTimeline> {
    let dp = spec.live.dp.max(2);
    let mut by_step: DetectionTimeline = BTreeMap::new();
    let mut push = |step: u64, rank: usize, kind: FailureKind, mode: LiveFailureMode| {
        let v = by_step.entry(step).or_default();
        if !v.iter().any(|&(r, _, _)| r == rank) {
            v.push((rank, kind, mode));
        }
    };
    for (i, f) in spec.faults.iter().enumerate() {
        let rank = |d: usize| f.rank.unwrap_or(d) % dp;
        let step = f
            .at_step
            .with_context(|| format!("fault {i}: live path needs \"at_step\""))?;
        let kind = f.failure.unwrap_or(FailureKind::Segfault);
        match f.family {
            FaultFamily::Crash => push(step, rank(i + 1), kind, LiveFailureMode::Die),
            FaultFamily::Cascade => {
                for j in 0..f.nodes {
                    push(
                        step + j as u64,
                        (rank(i + 1) + j) % dp,
                        kind,
                        LiveFailureMode::Die,
                    );
                }
            }
            FaultFamily::Flap => {
                for j in 0..f.times {
                    push(
                        step + j as u64 * f.period_steps.max(1),
                        rank(i + 1),
                        kind,
                        LiveFailureMode::Die,
                    );
                }
            }
            FaultFamily::Straggler => {
                push(step, rank(i + 1), FailureKind::Timeout, LiveFailureMode::Hang)
            }
            other => bail!(
                "fault {i}: {:?} has no live detection equivalent — run this \
                 scenario on the simulator path",
                other.name()
            ),
        }
    }
    Ok(by_step)
}

/// One live detection → rebuild → restore episode.
#[derive(Debug, Clone)]
pub struct LiveDetectionOutcome {
    /// Failure step the episode recovered (spec `at_step`).
    pub step: u64,
    /// Rendezvous epoch the episode converged in.
    pub epoch: u64,
    /// What the wire monitor reported, in detection order.
    pub detections: Vec<Detection>,
    /// Max measured last-good-heartbeat → detection latency (s).
    pub detection_s: f64,
    pub rebuild_s: f64,
    pub restore_s: f64,
    /// Failure induced → every victim restored, end to end.
    pub total_s: f64,
    pub resume_step: u64,
    /// Ranks restored by the episode.
    pub restored: Vec<usize>,
    /// Flight-recorder trace id of the episode (0 while the recorder
    /// is off) — key into `telemetry::trace::{spans_for, events_for}`.
    pub trace_id: u64,
}

/// Drive the spec's failures through the *whole* live pipeline over
/// real sockets, with no xla dependency (DESIGN.md §10): per failure
/// step, synthetic worker agents (monitor board + real heartbeat
/// emitter each) push beats to a live `TcpStoreServer`; the victims
/// die or silently hang; the [`LeaseMonitor`] detects them on the
/// wire with a *measured* latency; and the episode chains straight
/// into an epoch-fenced group rebuild and a shard-aware state restore
/// on the same store — detection → rendezvous → restore as one
/// end-to-end episode. Companion of [`drive_group_rebuilds`] and
/// [`drive_restores`], which exercise the later stages in isolation.
pub fn drive_live_detection(spec: &ScenarioSpec) -> Result<Vec<LiveDetectionOutcome>> {
    let timeline = live_detection_timeline(spec)?;
    let dp = spec.live.dp.max(2);
    let par = ParallelismConfig::dp(dp);
    let server = TcpStoreServer::start()?;
    let eps = server.endpoints();
    let interval = Duration::from_millis(15);
    let mut mon = LeaseMonitor::new(LeaseConfig {
        interval,
        lease_misses: 3,
        stall_after: Duration::from_millis(120),
        stall_margin: 2,
    });
    let mut table = Ranktable::new(
        (0..dp)
            .map(|rank| RankEntry {
                rank,
                node: rank,
                device: 0,
                addr: format!("127.0.0.1:{}", 29000 + rank),
            })
            .collect(),
    );

    let mut boards: BTreeMap<usize, Arc<MonitorBoard>> = BTreeMap::new();
    let mut incarnations: BTreeMap<usize, u64> = BTreeMap::new();
    let mut emitters: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut next_inc = 0u64;
    // The initial fleet is one simulated *node*: its ranks' beats are
    // coalesced through a single node agent — one Batch frame per
    // interval for the whole fleet (DESIGN.md §11) — while respawned
    // replacements below run per-process emitters, so both beat
    // planes are exercised in one episode chain.
    let mut members: Vec<NodeRank> = Vec::with_capacity(dp);
    for rank in 0..dp {
        next_inc += 1;
        let b = MonitorBoard::new();
        mon.admit(rank, next_inc, Instant::now());
        members.push(NodeRank { rank, incarnation: next_inc, board: b.clone() });
        boards.insert(rank, b);
        incarnations.insert(rank, next_inc);
    }
    emitters.push(spawn_node_heartbeat(
        members,
        NodeAgentCfg { store: eps.clone(), interval },
    ));

    let mut epoch = 0u64;
    let mut sim_step = 0u64;
    let mut outcomes = Vec::with_capacity(timeline.len());
    for (step, victims) in timeline {
        // the fleet advances to the failure step; every lease gets a
        // fresh grace so prior episodes' clocks cannot leak in
        sim_step = sim_step.max(step);
        for b in boards.values() {
            b.step_tag.store(sim_step as i64, Ordering::SeqCst);
        }
        let now = Instant::now();
        for rank in 0..dp {
            mon.admit(rank, incarnations[&rank], now);
        }

        // induce the failures; the episode root span opens here so its
        // wall interval tracks `total_s`, with one child per phase
        let mut episode = trace::root("episode", "controller");
        episode.set_detail(format!("step={step} victims={}", victims.len()));
        let mut span_detect = episode.child("detection", "controller");
        let t0 = Instant::now();
        let mut hang_victims = Vec::new();
        for &(rank, kind, mode) in &victims {
            let b = &boards[&rank];
            match mode {
                LiveFailureMode::Die => {
                    if kind.category() == FailureCategory::Hardware {
                        b.device_error.store(kind_code(kind), Ordering::SeqCst);
                    }
                    b.alive.store(false, Ordering::SeqCst);
                }
                LiveFailureMode::Hang => hang_victims.push(rank),
            }
        }

        // detect on the wire while the survivors keep training
        let expected: BTreeSet<usize> = victims.iter().map(|&(r, _, _)| r).collect();
        let mut detections: Vec<Detection> = Vec::new();
        let deadline = t0 + Duration::from_secs(30);
        while detections.len() < expected.len() {
            if Instant::now() > deadline {
                bail!("live detection timed out at step {step}");
            }
            std::thread::sleep(interval);
            sim_step += 1;
            for (r, b) in &boards {
                if !expected.contains(r) {
                    b.step_tag.store(sim_step as i64, Ordering::SeqCst);
                }
            }
            for beat in server.beats() {
                mon.observe_beat(&beat);
            }
            for d in mon.scan(Instant::now()) {
                if expected.contains(&d.rank)
                    && !detections.iter().any(|e| e.rank == d.rank)
                {
                    detections.push(d);
                }
            }
        }
        let detection_s = detections.iter().filter_map(|d| d.latency_s).fold(0.0, f64::max);
        span_detect.set_detail(format!(
            "detected={} measured_s={detection_s:.4}",
            detections.len()
        ));
        span_detect.end();
        // a detected hang is evicted: the stuck worker is torn down
        // like any other victim before its rank is rebuilt
        for &rank in &hang_victims {
            boards[&rank].alive.store(false, Ordering::SeqCst);
        }

        // chain into the rendezvous rebuild on the same store
        let failed: Vec<usize> = expected.iter().copied().collect();
        let replacements: Vec<RankEntry> = failed
            .iter()
            .map(|&r| RankEntry {
                rank: r,
                node: dp + (epoch as usize + 1) * dp + r,
                device: 0,
                addr: format!("127.0.0.1:{}", 31000 + step as usize + r),
            })
            .collect();
        let mut span_rebuild = episode.child("rebuild", "controller");
        let t_rebuild = Instant::now();
        let out = rebuild_episode(
            &server.endpoints(),
            &table,
            &par,
            &failed,
            &replacements,
            epoch,
            &EpisodeConfig { live_survivors: dp, ..Default::default() },
        )?;
        let rebuild_s = t_rebuild.elapsed().as_secs_f64();
        span_rebuild.set_detail(format!("epoch={} failed={failed:?}", out.epoch));
        span_rebuild.end();
        epoch = out.epoch;
        table = out.table.clone();

        // mid-episode introspection: pull the store's live metrics
        // snapshot over the Stats wire op and pin it to the trace
        if let Some(ctx) = episode.ctx() {
            if let Ok(snap) =
                StoreSession::try_connect(&eps).and_then(|mut c| c.stats())
            {
                trace::event_in(
                    ctx,
                    "store-stats",
                    "controller",
                    format!(
                        "requests={} frames={} epoch={}",
                        snap.counter("store.requests"),
                        snap.counter("store.frames"),
                        snap.gauge("store.epoch"),
                    ),
                );
            }
        }

        // ... and straight into the shard restore at the survivors'
        // step, still on the same store and epoch
        let resume = sim_step;
        let survivor_steps: Vec<(usize, u64)> = (0..dp)
            .filter(|r| !failed.contains(r))
            .map(|r| (r, resume))
            .collect();
        if survivor_steps.is_empty() {
            bail!("live detection episode at step {step} left no survivors");
        }
        let states: BTreeMap<usize, Snapshot> = survivor_steps
            .iter()
            .map(|&(r, _)| (r, synthetic_snapshot(resume, CHAOS_STATE_ELEMS)))
            .collect();
        let plan = plan_shard_restore(&par, &survivor_steps, &failed);
        if !plan.replica_feasible() {
            bail!("live detection episode at step {step} has unsourced shards");
        }
        let mut span_restore = episode.child("restore", "controller");
        let stream_cfg = StreamConfig { trace: span_restore.ctx(), ..Default::default() };
        let t_restore = Instant::now();
        let fence = EpochFence::new(epoch);
        let rout = restore_episode(&eps, &plan, &states, epoch, &fence, &stream_cfg)
            .map_err(|e| anyhow!("{e}"))?;
        let restore_s = t_restore.elapsed().as_secs_f64();
        span_restore.set_detail(format!(
            "resume_step={} bytes={}",
            rout.resume_step,
            rout.bytes_moved()
        ));
        span_restore.end();
        let reference = states[&plan.transfers[0].source].content_hash();
        for (rank, snap) in &rout.restored {
            if snap.content_hash() != reference {
                bail!("rank {rank} diverged after live-detection restore");
            }
        }
        let total_s = t0.elapsed().as_secs_f64();
        let trace_id = episode.trace_id();
        episode.set_detail(format!("epoch={epoch} total_s={total_s:.4}"));
        episode.end();
        let reg = global();
        reg.observe("episode.detection_s", detection_s);
        reg.observe("episode.rebuild_s", rebuild_s);
        reg.observe("episode.restore_s", restore_s);
        reg.observe("episode.total_s", total_s);
        reg.inc("episode.recovered");

        // respawn the victims under fresh incarnations
        for &rank in &failed {
            next_inc += 1;
            let b = MonitorBoard::new();
            b.step_tag.store(resume as i64, Ordering::SeqCst);
            mon.admit(rank, next_inc, Instant::now());
            emitters.push(spawn_heartbeat(
                rank,
                b.clone(),
                HeartbeatCfg { store: eps.clone(), interval, incarnation: next_inc },
            ));
            boards.insert(rank, b);
            incarnations.insert(rank, next_inc);
        }

        outcomes.push(LiveDetectionOutcome {
            step,
            epoch,
            detections,
            detection_s,
            rebuild_s,
            restore_s,
            total_s,
            resume_step: rout.resume_step,
            restored: rout.restored.keys().copied().collect(),
            trace_id,
        });
    }

    for b in boards.values() {
        b.alive.store(false, Ordering::SeqCst);
    }
    drop(server);
    for e in emitters {
        let _ = e.join();
    }
    Ok(outcomes)
}

// ------------------------------------------------------------------
// Coordination-plane failover: store/controller crashes mid-recovery
// ------------------------------------------------------------------

/// Outcome of a store-primary crash injected into a live rendezvous.
#[derive(Debug, Clone)]
pub struct StoreFailoverOutcome {
    /// Address of the primary killed while waits were parked on it.
    pub killed: std::net::SocketAddr,
    /// Value the parked rendezvous wait woke with after failing over
    /// to the promoted replica (exactly one wake).
    pub sentinel: Vec<u8>,
    /// Rebuild episodes completed on the failed-over plane.
    pub episodes: Vec<RebuildOutcome>,
}

/// Drive the spec's failure timeline as group rebuilds over a
/// *replicated* coordination plane (primary + one quorum replica),
/// with the primary killed while a rendezvous-plane wait is parked on
/// it: the parked session must fail over to the promoted replica and
/// wake exactly once, and every subsequent epoch-fenced rebuild
/// episode must converge on the failed-over store with the survivor
/// re-key budget intact (3 logical ops / 2 RTTs, DESIGN.md §13). The
/// live teeth of the `store_crash_mid_rendezvous` scenario — and,
/// run over the other live-capable specs, the proof that each passes
/// with a coordinator crash injected.
pub fn drive_store_crash_mid_rendezvous(
    spec: &ScenarioSpec,
) -> Result<StoreFailoverOutcome> {
    let plans = live_failure_plans(spec)?;
    let timeline = rebuild_timeline(&plans);
    let dp = spec.live.dp.max(1);
    let par = ParallelismConfig::dp(dp);
    let mut table = Ranktable::new(
        (0..dp)
            .map(|rank| RankEntry {
                rank,
                node: rank,
                device: 0,
                addr: format!("127.0.0.1:{}", 29000 + rank),
            })
            .collect(),
    );
    let mut set = ReplicaSet::start(1)?;
    let eps = set.endpoints();

    // Park a rendezvous-plane wait on the primary, exactly like a
    // survivor blocked on a release barrier when the store dies.
    let parked_eps = eps.clone();
    let parked = std::thread::spawn(move || -> Result<Vec<u8>> {
        let mut s = StoreSession::connect(parked_eps)?;
        Ok(s.wait("rdzv/failover-sentinel")?.to_vec())
    });
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let parked_now = set
            .primary_server()
            .map(|p| p.metrics_snapshot().gauge("store.parked_waiters"))
            .unwrap_or(0);
        if parked_now >= 1 {
            break;
        }
        if Instant::now() > deadline {
            bail!("sentinel wait never parked on the primary");
        }
        std::thread::sleep(Duration::from_millis(2));
    }
    let killed = set
        .kill_primary()
        .ok_or_else(|| anyhow!("replica set has no primary to kill"))?;

    // The release lands on whichever node discovery promotes; the
    // parked session replays its wait there and wakes exactly once.
    let mut releaser = StoreSession::connect(eps.clone())?;
    releaser.set("rdzv/failover-sentinel", b"released")?;
    let sentinel =
        parked.join().map_err(|_| anyhow!("parked waiter panicked"))??;

    // ... then the whole failure timeline rebuilds on the failed-over
    // plane, survivor budget intact.
    let mut epoch = 0u64;
    let mut episodes = Vec::with_capacity(timeline.len());
    for (step, mut failed) in timeline {
        failed.sort_unstable();
        let replacements: Vec<RankEntry> = failed
            .iter()
            .map(|&r| RankEntry {
                rank: r,
                node: dp + (epoch as usize + 1) * dp + r,
                device: 0,
                addr: format!("127.0.0.1:{}", 31000 + step as usize + r),
            })
            .collect();
        let out = rebuild_episode(
            &eps,
            &table,
            &par,
            &failed,
            &replacements,
            epoch,
            &EpisodeConfig { live_survivors: dp, ..Default::default() },
        )?;
        epoch = out.epoch;
        table = out.table.clone();
        episodes.push(out);
    }
    Ok(StoreFailoverOutcome { killed, sentinel, episodes })
}

/// Outcome of a controller crash injected between rebuild and restore.
#[derive(Debug, Clone)]
pub struct ControllerFailoverOutcome {
    /// Failure step the adopted episode recovered (spec `at_step`).
    pub step: u64,
    /// Epoch the standby adopted and restored at.
    pub epoch: u64,
    /// Phase of the adopted checkpoint (always `Restore` here).
    pub adopted_phase: EpisodePhase,
    /// Leases the standby re-opened from the replicated table.
    pub adopted_leases: usize,
    /// Ranks restored by the standby.
    pub restored: Vec<usize>,
    pub bytes_moved: u64,
    /// Every restored replica matched the survivors bit for bit.
    pub bit_exact: bool,
}

/// Drive the spec's failures as half-finished recovery episodes a
/// *standby controller* must adopt and finish: per failure step, the
/// first controller completes detection and group rebuild, persists
/// the episode checkpoint and lease table to the replicated store,
/// and crashes together with the store primary before any shard
/// moves. The standby adopts the coordination state from the promoted
/// replica, resumes the restore at the adopted epoch, and the
/// restored replicas must be bit-exact (DESIGN.md §13). The live
/// teeth of the `controller_crash_mid_restore` scenario.
pub fn drive_controller_crash_mid_restore(
    spec: &ScenarioSpec,
) -> Result<Vec<ControllerFailoverOutcome>> {
    let plans = live_failure_plans(spec)?;
    let timeline = rebuild_timeline(&plans);
    let dp = spec.live.dp.max(2);
    let par = ParallelismConfig::dp(dp);
    let mut outcomes = Vec::with_capacity(timeline.len());
    for (step, mut failed) in timeline {
        failed.sort_unstable();
        // Fresh replicated plane per episode: each crash consumes its
        // primary (and the controller that owned it).
        let mut set = ReplicaSet::start(1)?;
        let eps = set.endpoints();
        let table = Ranktable::new(
            (0..dp)
                .map(|rank| RankEntry {
                    rank,
                    node: rank,
                    device: 0,
                    addr: format!("127.0.0.1:{}", 29000 + rank),
                })
                .collect(),
        );
        let replacements: Vec<RankEntry> = failed
            .iter()
            .map(|&r| RankEntry {
                rank: r,
                node: 2 * dp + r,
                device: 0,
                addr: format!("127.0.0.1:{}", 31000 + step as usize + r),
            })
            .collect();

        // Phase 1 — the first controller: groups rebuilt, episode
        // checkpoint + lease table persisted to the replicated store.
        let out = rebuild_episode(
            &eps,
            &table,
            &par,
            &failed,
            &replacements,
            0,
            &EpisodeConfig { live_survivors: dp, ..Default::default() },
        )?;
        let mut ctl = StoreSession::connect(eps.clone())?;
        let leases: Vec<(usize, u64)> =
            (0..dp).filter(|r| !failed.contains(r)).map(|r| (r, 1)).collect();
        ctl.set(K_LEASES, &encode_leases(&leases))?;
        let ck = EpisodeCheckpoint {
            phase: EpisodePhase::Restore,
            epoch: out.epoch,
            dead: failed.clone(),
            resume_step: step,
            detection_s: 0.05,
            rebuild_s: out.wall_s,
        };
        ctl.set(K_EPISODE, &ck.encode())?;
        drop(ctl);

        // ... and crashes together with the store primary.
        set.kill_primary()
            .ok_or_else(|| anyhow!("replica set has no primary to kill"))?;

        // Phase 2 — the standby adopts from the promoted replica and
        // finishes the restore at the adopted epoch.
        let mut standby = StandbyController::adopt(&eps)?;
        let adopted = standby
            .adopted
            .episode
            .clone()
            .ok_or_else(|| anyhow!("standby adopted no episode checkpoint"))?;
        let survivor_steps: Vec<(usize, u64)> = (0..dp)
            .filter(|r| !adopted.dead.contains(r))
            .map(|r| (r, adopted.resume_step))
            .collect();
        if survivor_steps.is_empty() {
            bail!("controller failover episode at step {step} left no survivors");
        }
        let states: BTreeMap<usize, Snapshot> = survivor_steps
            .iter()
            .map(|&(r, _)| {
                (r, synthetic_snapshot(adopted.resume_step, CHAOS_STATE_ELEMS))
            })
            .collect();
        let plan = plan_shard_restore(&par, &survivor_steps, &adopted.dead);
        if !plan.replica_feasible() {
            bail!("controller failover episode at step {step} has unsourced shards");
        }
        let fence = EpochFence::new(adopted.epoch);
        let adopted_leases = standby.adopted.leases.len();
        let rout =
            standby.resume_restore(&plan, &states, &fence, &StreamConfig::default())?;
        let reference = states[&plan.transfers[0].source].content_hash();
        let bit_exact =
            rout.restored.values().all(|s| s.content_hash() == reference);

        // The finished episode's checkpoint must be gone from the
        // failed-over plane.
        let mut check = StoreSession::connect(eps)?;
        if check.get(K_EPISODE)?.is_some() {
            bail!("episode checkpoint survived the standby's completion");
        }
        outcomes.push(ControllerFailoverOutcome {
            step,
            epoch: adopted.epoch,
            adopted_phase: adopted.phase,
            adopted_leases,
            restored: rout.restored.keys().copied().collect(),
            bytes_moved: rout.bytes_moved(),
            bit_exact,
        });
    }
    Ok(outcomes)
}

// ------------------------------------------------------------------
// Impaired plane: the same campaigns over degraded links (§15)
// ------------------------------------------------------------------

fn netem_section(spec: &ScenarioSpec) -> Result<&NetemSpec> {
    spec.netem.as_ref().ok_or_else(|| {
        anyhow!(
            "scenario {:?} has no netem section — run it with the unimpaired \
             live drivers",
            spec.name
        )
    })
}

/// Policy of one rank's link: the per-rank override when present, else
/// the spec default, else a perfect link.
fn rank_policy(n: &NetemSpec, rank: usize) -> LinkPolicy {
    n.links
        .iter()
        .find(|l| l.rank == Some(rank))
        .map(|l| l.policy)
        .or(n.default)
        .unwrap_or_default()
}

/// Worst round-trip budget over every link the spec impairs — what the
/// §15 [`Timeouts`] scaling is fed.
fn worst_rtt(n: &NetemSpec) -> Duration {
    let budget = |p: &LinkPolicy| {
        p.rtt() + Duration::from_secs_f64(2.0 * p.jitter_ms / 1000.0)
    };
    let mut worst = n.default.as_ref().map(&budget).unwrap_or(Duration::ZERO);
    for l in &n.links {
        worst = worst.max(budget(&l.policy));
    }
    worst
}

/// The spec-default impairment map (per-rank overrides excluded) —
/// what shared-plane traffic (store clients, heartbeats) dials through.
fn shared_map(n: &NetemSpec) -> Arc<NetemMap> {
    let map = NetemMap::new(n.default.unwrap_or_default());
    for l in &n.links {
        if l.rank.is_none() {
            map.set_default(l.policy);
        }
    }
    map
}

/// One impaired-detection episode: a crash caught through a degraded
/// heartbeat plane.
#[derive(Debug, Clone)]
pub struct NetemDetectionOutcome {
    /// Failure step the episode recovered (spec `at_step`).
    pub step: u64,
    /// Rendezvous epoch the chained rebuild converged in.
    pub epoch: u64,
    pub detections: Vec<Detection>,
    /// Max measured last-good-heartbeat -> detection latency (s).
    pub detection_s: f64,
    pub rebuild_s: f64,
    /// Survivors the monitor ever flagged — must stay empty: a beat
    /// delayed by retransmission is not a dead rank.
    pub false_evictions: Vec<usize>,
    /// Lease budget the driver scaled to for the impaired plane (s).
    pub lease_budget_s: f64,
}

/// Drive the spec's crashes through live wire detection over an
/// *impaired* heartbeat plane (DESIGN.md §15): every beat and store op
/// crosses a link shaped by the spec's `netem:` section. The lease
/// budget is scaled from the shaper's deterministic worst-case arrival
/// lag — one request plus one response charge, each capped at
/// [`MAX_CHARGE`] — so survivors whose beats are delayed by loss
/// retransmission can *never* falsely expire, while dead ranks still
/// expire and chain into an epoch-fenced rebuild on the same degraded
/// store, its barrier widened via [`Timeouts::scaled_for_rtt`].
pub fn drive_netem_detection(spec: &ScenarioSpec) -> Result<Vec<NetemDetectionOutcome>> {
    let n = netem_section(spec)?;
    let timeline = live_detection_timeline(spec)?;
    let dp = spec.live.dp.max(2);
    let par = ParallelismConfig::dp(dp);
    let server = TcpStoreServer::start()?;
    let map = shared_map(n);
    let dialer: Arc<dyn Dialer> = Arc::new(NetemDialer::over(
        Arc::new(crate::comms::DirectDialer),
        map.clone(),
    ));
    let eps = server.endpoints().with_dialer(dialer);

    // §15 deadline scaling: the lease budget must exceed the worst
    // arrival lag an impaired-but-alive emitter can accrue (egress +
    // ingress charge, each capped at MAX_CHARGE, plus one interval).
    let interval = Duration::from_millis(25).max(worst_rtt(n));
    let lag_bound = interval + 2 * MAX_CHARGE;
    let lease_misses =
        (lag_bound.as_secs_f64() / interval.as_secs_f64()).ceil() as u32 + 2;
    let lease_budget = interval * lease_misses;
    let timeouts = Timeouts::default().scaled_for_rtt(lag_bound);
    let mut mon = LeaseMonitor::new(LeaseConfig {
        interval,
        lease_misses,
        stall_after: lease_budget * 4,
        stall_margin: 2,
    });

    let mut table = Ranktable::new(
        (0..dp)
            .map(|rank| RankEntry {
                rank,
                node: rank,
                device: 0,
                addr: format!("127.0.0.1:{}", 29000 + rank),
            })
            .collect(),
    );
    let mut boards: BTreeMap<usize, Arc<MonitorBoard>> = BTreeMap::new();
    let mut incarnations: BTreeMap<usize, u64> = BTreeMap::new();
    let mut emitters: Vec<std::thread::JoinHandle<()>> = Vec::new();
    let mut next_inc = 0u64;
    let mut members: Vec<NodeRank> = Vec::with_capacity(dp);
    for rank in 0..dp {
        next_inc += 1;
        let b = MonitorBoard::new();
        mon.admit(rank, next_inc, Instant::now());
        members.push(NodeRank { rank, incarnation: next_inc, board: b.clone() });
        boards.insert(rank, b);
        incarnations.insert(rank, next_inc);
    }
    emitters.push(spawn_node_heartbeat(
        members,
        NodeAgentCfg { store: eps.clone(), interval },
    ));

    let mut epoch = 0u64;
    let mut sim_step = 0u64;
    let mut false_evictions: Vec<usize> = Vec::new();
    let mut outcomes = Vec::with_capacity(timeline.len());
    for (step, victims) in timeline {
        sim_step = sim_step.max(step);
        for b in boards.values() {
            b.step_tag.store(sim_step as i64, Ordering::SeqCst);
        }
        let now = Instant::now();
        for rank in 0..dp {
            mon.admit(rank, incarnations[&rank], now);
        }

        let t0 = Instant::now();
        for &(rank, kind, mode) in &victims {
            if mode == LiveFailureMode::Hang {
                bail!(
                    "netem detection drives crash faults only — straggler hangs \
                     belong to drive_live_detection"
                );
            }
            let b = &boards[&rank];
            if kind.category() == FailureCategory::Hardware {
                b.device_error.store(kind_code(kind), Ordering::SeqCst);
            }
            b.alive.store(false, Ordering::SeqCst);
        }

        let expected: BTreeSet<usize> = victims.iter().map(|&(r, _, _)| r).collect();
        let mut detections: Vec<Detection> = Vec::new();
        let deadline = t0 + Duration::from_secs(30).max(lease_budget * 4);
        while detections.len() < expected.len() {
            if Instant::now() > deadline {
                bail!("impaired detection timed out at step {step}");
            }
            std::thread::sleep(interval);
            sim_step += 1;
            for (r, b) in &boards {
                if !expected.contains(r) {
                    b.step_tag.store(sim_step as i64, Ordering::SeqCst);
                }
            }
            for beat in server.beats() {
                mon.observe_beat(&beat);
            }
            for d in mon.scan(Instant::now()) {
                if expected.contains(&d.rank) {
                    if !detections.iter().any(|e| e.rank == d.rank) {
                        detections.push(d);
                    }
                } else if !false_evictions.contains(&d.rank) {
                    false_evictions.push(d.rank);
                }
            }
        }
        let detection_s =
            detections.iter().filter_map(|d| d.latency_s).fold(0.0, f64::max);

        // ... chained into the rendezvous rebuild over the same
        // degraded store, its supervised barrier widened for the link.
        let failed: Vec<usize> = expected.iter().copied().collect();
        let replacements: Vec<RankEntry> = failed
            .iter()
            .map(|&r| RankEntry {
                rank: r,
                node: dp + (epoch as usize + 1) * dp + r,
                device: 0,
                addr: format!("127.0.0.1:{}", 31000 + step as usize + r),
            })
            .collect();
        let t_rebuild = Instant::now();
        let out = rebuild_episode(
            &eps,
            &table,
            &par,
            &failed,
            &replacements,
            epoch,
            &EpisodeConfig::from_timeouts(&timeouts, dp),
        )?;
        let rebuild_s = t_rebuild.elapsed().as_secs_f64();
        epoch = out.epoch;
        table = out.table.clone();

        let reg = global();
        reg.observe("netem.detection_s", detection_s);
        reg.observe("netem.rebuild_s", rebuild_s);

        // respawn the victims under fresh incarnations, still impaired
        for &rank in &failed {
            next_inc += 1;
            let b = MonitorBoard::new();
            b.step_tag.store(sim_step as i64, Ordering::SeqCst);
            mon.admit(rank, next_inc, Instant::now());
            emitters.push(spawn_heartbeat(
                rank,
                b.clone(),
                HeartbeatCfg { store: eps.clone(), interval, incarnation: next_inc },
            ));
            boards.insert(rank, b);
            incarnations.insert(rank, next_inc);
        }

        outcomes.push(NetemDetectionOutcome {
            step,
            epoch,
            detections,
            detection_s,
            rebuild_s,
            false_evictions: false_evictions.clone(),
            lease_budget_s: lease_budget.as_secs_f64(),
        });
    }

    for b in boards.values() {
        b.alive.store(false, Ordering::SeqCst);
    }
    drop(server);
    for e in emitters {
        let _ = e.join();
    }
    Ok(outcomes)
}

/// Outcome of a shard restore driven across an impaired (WAN-profile)
/// link, with the wire latencies the §6 calibration consumes.
#[derive(Debug, Clone)]
pub struct NetemRestoreOutcome {
    /// Round-trip the spec's worst link imposes (s).
    pub rtt_s: f64,
    /// Measured mean store-op round-trip over the impaired link (s) —
    /// the wire replacement for the §6 `tcp_store_per_link_s` constant.
    pub store_op_s: f64,
    pub rebuild_s: f64,
    /// Wall of the impaired shard fetch, dial included (s).
    pub fetch_wall_s: f64,
    pub bytes: u64,
    /// The restored snapshot matched the source bit for bit.
    pub bit_exact: bool,
    pub epoch: u64,
}

/// Drive the spec's first failure as a real recovery whose every wire
/// crossing pays the spec's `netem:` impairment (DESIGN.md §15): store
/// ops and the rendezvous rebuild run over the degraded link, then the
/// replacement pulls its shard through [`fetch_from_addr_via`] on the
/// same impaired dialer — io-stall and accept deadlines widened via
/// [`StreamConfig::from_timeouts`] — and must land bit-exact. The
/// measured store-op and fetch walls are the §6 calibration inputs.
pub fn drive_netem_restore(spec: &ScenarioSpec) -> Result<NetemRestoreOutcome> {
    let n = netem_section(spec)?;
    let plans = live_failure_plans(spec)?;
    let (step, mut failed) = rebuild_timeline(&plans)
        .into_iter()
        .next()
        .ok_or_else(|| anyhow!("scenario {:?} schedules no failures", spec.name))?;
    failed.sort_unstable();
    let dp = spec.live.dp.max(2);
    let par = ParallelismConfig::dp(dp);
    let server = TcpStoreServer::start()?;
    let map = shared_map(n);
    let dialer: Arc<dyn Dialer> = Arc::new(NetemDialer::over(
        Arc::new(crate::comms::DirectDialer),
        map.clone(),
    ));
    let eps = server.endpoints().with_dialer(dialer.clone());
    let rtt = worst_rtt(n);
    let timeouts = Timeouts::default().scaled_for_rtt(rtt);

    // Measured wire latency per store op over the degraded link.
    const PROBE_OPS: u32 = 8;
    let mut probe = StoreSession::connect(eps.clone())?;
    let t_probe = Instant::now();
    for i in 0..PROBE_OPS {
        probe.set(&format!("netem/probe/{i}"), b"x")?;
    }
    let store_op_s = t_probe.elapsed().as_secs_f64() / f64::from(PROBE_OPS);
    drop(probe);

    let table = Ranktable::new(
        (0..dp)
            .map(|rank| RankEntry {
                rank,
                node: rank,
                device: 0,
                addr: format!("127.0.0.1:{}", 29000 + rank),
            })
            .collect(),
    );
    let replacements: Vec<RankEntry> = failed
        .iter()
        .map(|&r| RankEntry {
            rank: r,
            node: dp + r,
            device: 0,
            addr: format!("127.0.0.1:{}", 31000 + step as usize + r),
        })
        .collect();
    let t_rebuild = Instant::now();
    let out = rebuild_episode(
        &eps,
        &table,
        &par,
        &failed,
        &replacements,
        0,
        &EpisodeConfig::from_timeouts(&timeouts, dp),
    )?;
    let rebuild_s = t_rebuild.elapsed().as_secs_f64();
    let epoch = out.epoch;

    // The replacement's shard fetch crosses the same impaired link:
    // a local source serves, the fetch dials through the netem map.
    let snap = synthetic_snapshot(step, CHAOS_STATE_ELEMS);
    let reference = snap.content_hash();
    let shard = ShardId { pp: 0, tp: 0, zero: 0 };
    let fence = EpochFence::new(epoch);
    let cfg = StreamConfig::from_timeouts(&timeouts);
    let listener = std::net::TcpListener::bind("127.0.0.1:0")
        .context("binding netem restore source")?;
    let src_addr = listener.local_addr()?;
    let serve_fence = fence.clone();
    let source = std::thread::spawn(move || {
        serve_listener(&listener, &snap, shard, epoch, 1, &serve_fence, &cfg)
    });
    let expect = Expect { epoch, shard, step: Some(step) };
    let t_fetch = Instant::now();
    let (got, stats) = fetch_from_addr_via(&*dialer, src_addr, &expect, &fence, &cfg)
        .map_err(|e| anyhow!("impaired fetch: {e}"))?;
    let fetch_wall_s = t_fetch.elapsed().as_secs_f64();
    source
        .join()
        .map_err(|_| anyhow!("netem restore source panicked"))?
        .map_err(|e| anyhow!("impaired serve: {e}"))?;

    let reg = global();
    reg.observe("netem.store_op_s", store_op_s);
    reg.observe("netem.fetch_wall_s", fetch_wall_s);
    Ok(NetemRestoreOutcome {
        rtt_s: rtt.as_secs_f64(),
        store_op_s,
        rebuild_s,
        fetch_wall_s,
        bytes: stats.bytes,
        bit_exact: got.content_hash() == reference,
        epoch,
    })
}

/// Outcome of a rendezvous barrier crossed by a partition heal.
#[derive(Debug, Clone)]
pub struct NetemPartitionOutcome {
    /// Ranks whose links were severed until the heal.
    pub healed_ranks: Vec<usize>,
    /// Partition start -> every rank arrived at the barrier (s).
    pub join_wall_s: f64,
    /// Seconds after start at which partitions lifted.
    pub heal_after_s: f64,
    /// Rank -> release payload; every rank must wake exactly once.
    pub wakes: Vec<(usize, Vec<u8>)>,
}

/// Drive a live rendezvous barrier across a partition heal (DESIGN.md
/// §15): every rank dials the store through its *own* link policy, the
/// severed ranks' connects fail until the heal thread lifts partitions
/// mid-rendezvous, and their jittered reconnects must still land the
/// arrive + parked-wait protocol inside the [`Timeouts`]-scaled join
/// deadline — one release, every rank wakes exactly once, no abort.
pub fn drive_netem_partition_heal(spec: &ScenarioSpec) -> Result<NetemPartitionOutcome> {
    let n = netem_section(spec)?;
    let dp = spec.live.dp.max(2);
    let server = TcpStoreServer::start()?;
    let base_eps = server.endpoints();
    let heal_after = Duration::from_secs_f64(n.heal_after_s.unwrap_or(0.0).max(0.0));

    // Per-rank planes: each rank's link carries its own policy.
    let mut maps: Vec<Arc<NetemMap>> = Vec::with_capacity(dp);
    let mut healed_ranks = Vec::new();
    for rank in 0..dp {
        let p = rank_policy(n, rank);
        if p.partition != Partition::None {
            healed_ranks.push(rank);
        }
        maps.push(NetemMap::new(p));
    }
    if healed_ranks.is_empty() {
        bail!("scenario {:?} severs no link — nothing to heal", spec.name);
    }
    let timeouts = Timeouts::default().scaled_for_rtt(worst_rtt(n));

    let t0 = Instant::now();
    let heal_maps = maps.clone();
    let healer = std::thread::spawn(move || {
        std::thread::sleep(heal_after);
        for m in &heal_maps {
            m.heal_partitions();
        }
    });

    // dp participants race the barrier; the severed ones ride the heal.
    let mut joins = Vec::with_capacity(dp);
    for (rank, map) in maps.iter().enumerate() {
        let eps = base_eps
            .clone()
            .with_dialer(Arc::new(NetemDialer::over(
                Arc::new(crate::comms::DirectDialer),
                map.clone(),
            )));
        joins.push(std::thread::spawn(move || -> Result<(usize, Vec<u8>)> {
            let mut s = StoreSession::connect(eps)?;
            s.set(&format!("netem/arrive/{rank}"), b"here")?;
            let v = s.wait("netem/release")?;
            Ok((rank, v.to_vec()))
        }));
    }

    // The coordinator supervises the barrier on an unimpaired link and
    // releases once — inside the scaled join deadline or not at all.
    let mut coord = StoreSession::connect(base_eps)?;
    let deadline = t0 + timeouts.join_deadline;
    let mut arrived: BTreeSet<usize> = BTreeSet::new();
    while arrived.len() < dp {
        if Instant::now() > deadline {
            bail!(
                "impaired rendezvous missed the scaled join deadline: {} of \
                 {dp} ranks arrived",
                arrived.len()
            );
        }
        for rank in 0..dp {
            if !arrived.contains(&rank)
                && coord.get(&format!("netem/arrive/{rank}"))?.is_some()
            {
                arrived.insert(rank);
            }
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    let join_wall_s = t0.elapsed().as_secs_f64();
    coord.set("netem/release", b"go")?;

    let mut wakes = Vec::with_capacity(dp);
    for j in joins {
        wakes.push(j.join().map_err(|_| anyhow!("netem participant panicked"))??);
    }
    wakes.sort_by_key(|&(r, _)| r);
    healer.join().map_err(|_| anyhow!("netem healer panicked"))?;
    global().observe("netem.join_wall_s", join_wall_s);
    Ok(NetemPartitionOutcome {
        healed_ranks,
        join_wall_s,
        heal_after_s: heal_after.as_secs_f64(),
        wakes,
    })
}

/// Outcome of the replica-group-wipeout drill (DESIGN.md §16): every
/// rank holding one ZeRO shard dies mid-step and the shard comes back
/// bit-exact from the erasure-stripe directory with zero checkpoint
/// reads — once over the network from surviving depots, and once more
/// from a warm spare's prefetched local cache.
#[derive(Debug, Clone)]
pub struct WipeoutOutcome {
    /// Recovery epoch the rebuild converged in.
    pub epoch: u64,
    /// Failure step the shard was rebuilt at.
    pub step: u64,
    /// The wiped-out shard.
    pub shard: ShardId,
    /// Ranks killed — the shard's entire replica group.
    pub victims: Vec<usize>,
    /// Stripes pushed in full across the pre-failure shipping passes.
    pub stripes_shipped: usize,
    /// Stripes version-bumped by hash refresh instead of resent — the
    /// idle-step delta path.
    pub stripes_refreshed: usize,
    /// True iff the final plan sourced every shard without checkpoints.
    pub checkpoint_free: bool,
    /// `ckpt.file_reads` delta observed across the rebuild. Zero on a
    /// `scenario` run; under `cargo test` concurrent tests can leak
    /// reads into the shared counter, so assertions use
    /// `checkpoint_free` instead.
    pub ckpt_reads: u64,
    /// Content hash of the network-reconstructed shard.
    pub rebuilt_hash: u64,
    /// Content hash of the warm spare's local-cache rebuild.
    pub warm_spare_hash: u64,
    pub wall_s: f64,
}

/// Drive the spec's scripted failures as a whole-replica-group wipeout
/// against the live redundancy tier (DESIGN.md §16). The shard's ranks
/// stream erasure stripes to peer depots during healthy steps (full
/// pushes, then hash refreshes for unchanged stripes), a warm spare
/// prefetches the hottest set, and then the *entire* group dies at
/// once — the exact case replica-to-replica restore cannot source.
/// Recovery must fall through `plan_shard_restore` to the stripe
/// directory and rebuild the shard bit-exact with zero checkpoint
/// reads.
pub fn drive_replica_group_wipeout(spec: &ScenarioSpec) -> Result<WipeoutOutcome> {
    let t0 = Instant::now();
    let plans = live_failure_plans(spec)?;
    let timeline: Vec<(u64, Vec<usize>)> =
        rebuild_timeline(&plans).into_iter().collect();
    ensure!(
        timeline.len() == 1,
        "replica-group wipeout wants one simultaneous failure step, spec has {}",
        timeline.len()
    );
    let (step, mut victims) = timeline[0].clone();
    victims.sort_unstable();

    // Two-way sharded DP fleet: ranks {0,2} hold shard zero=0, ranks
    // {1,3} hold zero=1. The spec's victims must be exactly one
    // shard's replica group, else this drill proves nothing.
    let dp = spec.live.dp.max(2);
    let par = ParallelismConfig::dp(dp).with_zero(2);
    ensure!(
        par.replication_factor() >= 2,
        "wipeout drill needs dp >= 4 so the dead shard had live replicas \
         (spec live.dp = {dp})"
    );
    let shard = par.shard_id(victims[0]);
    let group: Vec<usize> = (0..par.world_size())
        .filter(|&r| par.shard_id(r) == shard)
        .collect();
    ensure!(
        group == victims,
        "victims {victims:?} are not a whole replica group (shard {shard:?} \
         lives on {group:?})"
    );

    let server = TcpStoreServer::start()?;
    let eps = server.endpoints();
    let mut session = StoreSession::try_connect(&eps)?;

    // Stripe depots on ranks outside the shard group plus warm spares,
    // placed deterministically and advertised through the store.
    let ship_epoch = 1u64;
    let fence = EpochFence::new(ship_epoch);
    let rcfg = RedundancyConfig::default();
    let total = rcfg.total();
    let holder_ids =
        stripe_holders(&par, shard, spec.cluster.spare_nodes.max(1), total)?;
    let mut depots = Vec::with_capacity(total);
    let mut holders = Vec::with_capacity(total);
    for &h in &holder_ids {
        let depot = StripeDepot::start(fence.clone(), rcfg.chunk_bytes)?;
        depot.advertise(&mut session, h)?;
        holders.push((h, depot.addr()));
        depots.push(depot);
    }

    // Healthy steady state: the doomed group ships stripes in idle
    // step time. An idle re-ship of unchanged state degrades to pure
    // hash refreshes; the failure step's state is a fresh full push.
    let mut shipper =
        StripeShipper::new(&eps, rcfg, shard, holders, fence.clone())?;
    let mut stripes_shipped = 0usize;
    let mut stripes_refreshed = 0usize;
    let warm = synthetic_snapshot(step.saturating_sub(1), CHAOS_STATE_ELEMS);
    for snap in [&warm, &warm, &synthetic_snapshot(step, CHAOS_STATE_ELEMS)] {
        let stats = shipper
            .ship(snap, ship_epoch)
            .map_err(|e| anyhow!("pre-failure ship at step {}: {e}", snap.step))?;
        stripes_shipped += stats.shipped;
        stripes_refreshed += stats.skipped;
    }

    // A warm spare prefetches the hottest stripes while all is well.
    let mut spare = WarmSpare::new();
    let mut spare_session = StoreSession::try_connect(&eps)?;
    let prefetched =
        spare.prefetch(&mut spare_session, ship_epoch, shard, total, &fence)?;
    ensure!(
        prefetched == total,
        "warm spare cached {prefetched} of {total} stripes"
    );

    // The whole replica group dies at once; detection bumps the epoch.
    let recovery_epoch = session.advance_epoch(ship_epoch + 1)?;
    fence.advance(recovery_epoch);
    let reads0 = global().counter("ckpt.file_reads").get();

    // Replica planning finds no live source for the wiped shard ...
    let survivor_steps: Vec<(usize, u64)> = (0..dp)
        .filter(|r| !victims.contains(r))
        .map(|r| (r, step))
        .collect();
    let mut plan = plan_shard_restore(&par, &survivor_steps, &victims);
    ensure!(
        !plan.checkpoint_free(),
        "replica planner unexpectedly sourced the wiped shard {shard:?}"
    );
    // ... and falls through to the stripe directory.
    cover_plan(&mut session, ship_epoch, total, &mut plan)?;
    ensure!(
        plan.checkpoint_free(),
        "stripe directory could not cover shard {shard:?}"
    );

    let expect = synthetic_snapshot(step, CHAOS_STATE_ELEMS).content_hash();
    let rc = plan
        .reconstructions
        .first()
        .ok_or_else(|| anyhow!("cover_plan left no reconstruction schedule"))?;
    let rebuilt =
        reconstruct_shard(&mut session, ship_epoch, rc, recovery_epoch, &fence)
            .map_err(|e| anyhow!("stripe rebuild of shard {shard:?}: {e}"))?;
    ensure!(rebuilt.step == step);
    let rebuilt_hash = rebuilt.content_hash();
    ensure!(
        rebuilt_hash == expect,
        "rebuilt shard {shard:?} diverges from the dead group's state"
    );

    // Warm-spare replacement join: the same bits from local cache
    // alone, even with every depot gone.
    depots.clear();
    let local = spare.recover_local(shard, step)?;
    let warm_spare_hash = local.content_hash();
    ensure!(warm_spare_hash == expect, "warm spare's local rebuild diverges");

    let ckpt_reads =
        global().counter("ckpt.file_reads").get().saturating_sub(reads0);
    Ok(WipeoutOutcome {
        epoch: recovery_epoch,
        step,
        shard,
        victims,
        stripes_shipped,
        stripes_refreshed,
        checkpoint_free: plan.checkpoint_free(),
        ckpt_reads,
        rebuilt_hash,
        warm_spare_hash,
        wall_s: t0.elapsed().as_secs_f64(),
    })
}

/// Run the spec's live plan end to end. Fails fast when the live
/// training plane (real xla + artifacts) is unavailable.
pub fn run_live(spec: &ScenarioSpec, seed: u64) -> Result<LiveOutcome> {
    let cfg = controller_config(spec, seed)?;
    let engine = TrainingEngine::load("tiny")
        .context("live training plane unavailable (needs artifacts + real xla)")?;
    let report = engine.run(cfg)?;
    let assertions = evaluate_live(spec, &report);
    Ok(LiveOutcome { report, assertions })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::library;
    use crate::coordinator::detection::DetectionPath;

    #[test]
    fn single_fault_maps_to_one_plan() {
        let spec = library::by_name("single_fault", 256).unwrap();
        let plans = live_failure_plans(&spec).unwrap();
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].rank, 1);
        assert_eq!(plans[0].step, 4);
        let cfg = controller_config(&spec, 3).unwrap();
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.failures.len(), 1);
    }

    #[test]
    fn flap_expands_to_spaced_plans_on_one_rank() {
        let spec = library::by_name("flaky_node", 256).unwrap();
        let plans = live_failure_plans(&spec).unwrap();
        assert_eq!(plans.len(), 3);
        assert!(plans.iter().all(|p| p.rank == plans[0].rank));
        assert_eq!(plans[1].step - plans[0].step, 4);
        assert!(plans.last().unwrap().step < spec.live.steps);
    }

    #[test]
    fn unsupported_families_are_rejected() {
        let spec = library::by_name("spare_exhaustion", 256).unwrap();
        assert!(live_failure_plans(&spec).is_err());
        let spec = library::by_name("straggler_degrade", 256).unwrap();
        assert!(live_failure_plans(&spec).is_err());
    }

    #[test]
    fn missing_at_step_is_an_error() {
        let spec = library::by_name("rolling_cascade", 256).unwrap();
        // cascade spec carries no live hints on purpose
        assert!(live_failure_plans(&spec).is_err());
    }

    #[test]
    fn live_bridge_drives_real_group_rebuild() {
        // End to end over real sockets: one failure -> one epoch-fenced
        // rendezvous in which survivors re-key and the failed rank's
        // replacement fully joins.
        let spec = library::by_name("single_fault", 256).unwrap();
        let episodes = drive_group_rebuilds(&spec).unwrap();
        assert_eq!(episodes.len(), 1);
        let ep = &episodes[0];
        assert_eq!(ep.epoch, 1);
        assert_eq!(ep.replacements, 1);
        assert!(ep.groups_rebuilt >= 1);
        assert_eq!(ep.survivor_ops_max, 3, "survivors must stay O(1) msgs");
        assert_eq!(ep.table.version, 2);
        assert!(ep.wall_s > 0.0);
    }

    #[test]
    fn live_bridge_drives_real_state_restore() {
        // single_fault: one victim at one step -> one restore episode
        // over real sockets, served by a surviving replica.
        let spec = library::by_name("single_fault", 256).unwrap();
        let episodes = drive_restores(&spec).unwrap();
        assert_eq!(episodes.len(), 1);
        let ep = &episodes[0];
        assert_eq!(ep.restored, vec![1]);
        assert_eq!(ep.resume_step, 4);
        assert_eq!(ep.aborted_attempts, 0);
        assert!(ep.bytes_moved > 0);
        assert!(!ep.sources.contains(&1), "victim cannot serve itself");
    }

    #[test]
    fn restore_under_churn_folds_second_failure() {
        // The headline churn semantics: the second failure strikes
        // while the first restore's streams are in flight. The epoch
        // bump aborts the attempt retryably, the episode folds both
        // victims in, and the replanned restore converges with every
        // replica bit-identical.
        let spec = library::by_name("restore_under_churn", 256).unwrap();
        let episodes = drive_restores_under_churn(&spec).unwrap();
        assert_eq!(episodes.len(), 1, "both failures fold into one episode");
        let ep = &episodes[0];
        assert_eq!(ep.aborted_attempts, 1, "first attempt must be superseded");
        assert_eq!(ep.restored, vec![1, 2]);
        assert!(ep.epoch >= 2, "abort bumps past the first epoch");
        for s in &ep.sources {
            assert!(![1usize, 2].contains(s), "victims cannot serve");
        }
    }

    #[test]
    fn restore_without_churn_runs_one_episode_per_failure_step() {
        let spec = library::by_name("restore_under_churn", 256).unwrap();
        let episodes = drive_restores(&spec).unwrap();
        assert_eq!(episodes.len(), 2);
        assert_eq!(episodes[0].restored, vec![1]);
        assert_eq!(episodes[1].restored, vec![2]);
        assert!(episodes.iter().all(|e| e.aborted_attempts == 0));
        assert!(episodes[1].epoch > episodes[0].epoch);
    }

    #[test]
    fn live_detection_recovers_silent_hang_end_to_end() {
        // The headline §10 semantics: an *alive* worker whose step tag
        // freezes while the group advances is detected via the
        // stall-vs-median rule over real sockets (liveness alone can
        // never see it), then recovered — rendezvous rebuild + shard
        // restore chained on the same store, one episode end to end.
        let spec = library::by_name("silent_hang", 256).unwrap();
        let episodes = drive_live_detection(&spec).unwrap();
        assert_eq!(episodes.len(), 1);
        let ep = &episodes[0];
        assert_eq!(ep.detections.len(), 1);
        let d = &ep.detections[0];
        assert_eq!(d.rank, 1);
        assert_eq!(d.path, DetectionPath::StepStall, "{d:?}");
        assert_eq!(d.kind, FailureKind::Timeout);
        assert!(d.latency_s.unwrap() > 0.0, "stall latency must be measured");
        assert!(ep.detection_s > 0.0 && ep.detection_s < 30.0);
        assert_eq!(ep.restored, vec![1]);
        assert_eq!(ep.epoch, 1);
        assert!(ep.resume_step >= 4, "survivors advanced past the hang");
        assert!(ep.rebuild_s > 0.0 && ep.restore_s > 0.0);
    }

    #[test]
    fn live_detection_measures_lease_expiry_for_process_death() {
        let spec = library::by_name("single_fault", 256).unwrap();
        let episodes = drive_live_detection(&spec).unwrap();
        assert_eq!(episodes.len(), 1);
        let ep = &episodes[0];
        let d = &ep.detections[0];
        assert_eq!(d.rank, 1);
        assert_eq!(d.path, DetectionPath::LeaseExpiry);
        assert_eq!(d.kind, FailureKind::Segfault);
        // measured from the last good heartbeat: at least the lease
        // (3 x 15ms), never the sampled model's number
        assert!(ep.detection_s >= 0.045, "measured {}", ep.detection_s);
        assert_eq!(ep.restored, vec![1]);
    }

    #[test]
    fn live_detection_classifies_hardware_kind_via_dying_gasp() {
        // restore_under_churn's first fault is a Network (hardware)
        // death, the second a Segfault: the device code pushed in the
        // emitter's dying gasp must win classification even though
        // death and report land in the same interval.
        let spec = library::by_name("restore_under_churn", 256).unwrap();
        let episodes = drive_live_detection(&spec).unwrap();
        assert_eq!(episodes.len(), 2);
        let first = &episodes[0].detections[0];
        assert_eq!(first.kind, FailureKind::Network, "{first:?}");
        assert_eq!(first.path, DetectionPath::DevicePlugin);
        assert!(first.via_device_plugin);
        let second = &episodes[1].detections[0];
        assert_eq!(second.kind, FailureKind::Segfault);
        assert_eq!(second.path, DetectionPath::LeaseExpiry);
        assert!(episodes[1].epoch > episodes[0].epoch);
    }

    #[test]
    fn live_detection_flap_redetects_across_incarnations() {
        // The same rank dies three times: each replacement's fresh
        // incarnation must be re-monitored (its predecessor's lease
        // and reported marks can never mask it).
        let spec = library::by_name("flaky_node", 256).unwrap();
        let episodes = drive_live_detection(&spec).unwrap();
        assert_eq!(episodes.len(), 3);
        for (i, ep) in episodes.iter().enumerate() {
            assert_eq!(ep.epoch, i as u64 + 1);
            assert_eq!(ep.restored.len(), 1);
            assert_eq!(ep.detections[0].path, DetectionPath::LeaseExpiry);
        }
    }

    #[test]
    fn live_bridge_flap_rebuilds_per_episode() {
        // flaky_node kills the same rank three times at spaced steps:
        // three rendezvous epochs, version advancing each time.
        let spec = library::by_name("flaky_node", 256).unwrap();
        let episodes = drive_group_rebuilds(&spec).unwrap();
        assert_eq!(episodes.len(), 3);
        for (i, ep) in episodes.iter().enumerate() {
            assert_eq!(ep.epoch, i as u64 + 1);
            assert_eq!(ep.replacements, 1);
        }
        assert_eq!(episodes.last().unwrap().table.version, 4);
    }

    #[test]
    fn store_primary_crash_mid_rendezvous_fails_over() {
        // The headline §13 semantics: the store primary dies while a
        // rendezvous wait is parked on it. The parked session fails
        // over to the promoted replica, wakes exactly once, and the
        // full rebuild runs on the failed-over plane with the
        // survivor re-key budget intact.
        let spec = library::by_name("store_crash_mid_rendezvous", 256).unwrap();
        let out = drive_store_crash_mid_rendezvous(&spec).unwrap();
        assert_eq!(out.sentinel.as_slice(), b"released");
        assert_eq!(out.episodes.len(), 1);
        let ep = &out.episodes[0];
        assert_eq!(ep.epoch, 1);
        assert_eq!(ep.replacements, 1);
        assert_eq!(ep.survivor_ops_max, 3, "re-key budget must survive failover");
        assert_eq!(ep.table.version, 2);
    }

    #[test]
    fn every_live_scenario_survives_a_coordinator_crash() {
        // Acceptance: each live-capable scenario's rebuild timeline
        // still converges — budgets intact — with a coordinator
        // (store-primary) crash injected mid-rendezvous.
        for name in
            ["single_fault", "double_fault", "flaky_node", "restore_under_churn"]
        {
            let spec = library::by_name(name, 256).unwrap();
            let out = drive_store_crash_mid_rendezvous(&spec).unwrap();
            assert_eq!(out.sentinel.as_slice(), b"released", "{name}");
            assert!(!out.episodes.is_empty(), "{name}");
            for ep in &out.episodes {
                assert_eq!(ep.survivor_ops_max, 3, "{name}: survivor budget");
                assert!(ep.groups_rebuilt + ep.groups_rekeyed > 0, "{name}");
            }
        }
    }

    #[test]
    fn netem_detection_under_loss_never_falsely_evicts() {
        // The §15 headline: a crash is still caught through a plane
        // dropping 30% of its chunks, while survivors whose beats are
        // delayed by retransmission are never falsely expired — the
        // lease budget scales from the shaper's deterministic worst
        // charge instead of loopback constants.
        let spec = library::by_name("detection_under_loss", 256).unwrap();
        let episodes = drive_netem_detection(&spec).unwrap();
        assert_eq!(episodes.len(), 1);
        let ep = &episodes[0];
        assert_eq!(ep.detections.len(), 1);
        assert_eq!(ep.detections[0].rank, 1);
        assert_eq!(ep.detections[0].path, DetectionPath::LeaseExpiry);
        assert!(ep.false_evictions.is_empty(), "{:?}", ep.false_evictions);
        assert!(ep.detection_s > 0.0 && ep.detection_s < 30.0);
        assert!(ep.lease_budget_s > 4.0, "budget must cover 2x MAX_CHARGE");
        assert_eq!(ep.epoch, 1, "rebuild must converge on the lossy store");
        assert!(ep.rebuild_s > 0.0);
    }

    #[test]
    fn netem_restore_over_wan_is_bit_exact_and_pays_the_wire() {
        // Rebuild + shard fetch over a 50ms-RTT jittery WAN link: the
        // transfer must land bit-exact and the measured walls must
        // actually reflect the wire (they are the §6 calibration
        // inputs), with every deadline widened via Timeouts.
        let spec = library::by_name("restore_over_wan", 256).unwrap();
        let out = drive_netem_restore(&spec).unwrap();
        assert!(out.bit_exact, "WAN fetch must stay bit-exact");
        assert!(out.bytes > 0);
        assert_eq!(out.epoch, 1);
        assert!(out.rtt_s > 0.04, "spec link must impose a real RTT");
        // the dial alone pays one full RTT (50ms) deterministically
        assert!(out.fetch_wall_s >= 0.04, "measured {}", out.fetch_wall_s);
        // each store op crosses the link twice (request + response)
        assert!(out.store_op_s >= 0.02, "measured {}", out.store_op_s);
        assert!(out.rebuild_s > 0.0);
    }

    #[test]
    fn netem_partition_heal_rendezvous_releases_once() {
        // One rank's link is severed when the barrier opens and heals
        // mid-rendezvous onto a slow link: its jittered reconnect must
        // land inside the scaled join deadline, and the single release
        // wakes every rank exactly once.
        let spec = library::by_name("partition_heal_rendezvous", 256).unwrap();
        let out = drive_netem_partition_heal(&spec).unwrap();
        assert_eq!(out.healed_ranks, vec![2]);
        assert_eq!(out.wakes.len(), 4);
        for (rank, payload) in &out.wakes {
            assert_eq!(payload.as_slice(), b"go", "rank {rank}");
        }
        // the severed rank cannot arrive before the heal fires
        assert!(
            out.join_wall_s >= out.heal_after_s * 0.95,
            "join {} vs heal {}",
            out.join_wall_s,
            out.heal_after_s
        );
    }

    #[test]
    fn netem_drivers_demand_a_netem_section() {
        let spec = library::by_name("single_fault", 256).unwrap();
        assert!(drive_netem_detection(&spec).is_err());
        assert!(drive_netem_restore(&spec).is_err());
        assert!(drive_netem_partition_heal(&spec).is_err());
    }

    #[test]
    fn controller_crash_mid_restore_is_adopted_and_finished() {
        // The standby controller adopts the lease table and in-flight
        // episode checkpoint from the promoted replica and drives the
        // half-finished restore to a bit-exact finish.
        let spec = library::by_name("controller_crash_mid_restore", 256).unwrap();
        let episodes = drive_controller_crash_mid_restore(&spec).unwrap();
        assert_eq!(episodes.len(), 1);
        let ep = &episodes[0];
        assert_eq!(ep.step, 4);
        assert_eq!(ep.epoch, 1);
        assert_eq!(ep.adopted_phase, EpisodePhase::Restore);
        assert_eq!(ep.adopted_leases, 3, "survivor leases adopted");
        assert_eq!(ep.restored, vec![1]);
        assert!(ep.bit_exact, "restore must stay bit-exact across failover");
        assert!(ep.bytes_moved > 0);
    }

    #[test]
    fn replica_group_wipeout_rebuilds_bit_exact_without_checkpoints() {
        // Both ranks holding shard zero=1 die at step 6. The replica
        // planner has no live source; the stripe directory covers the
        // shard and the rebuild matches the dead group's bits — no
        // checkpoint in the loop.
        let spec = library::by_name("replica_group_wipeout", 256).unwrap();
        let out = drive_replica_group_wipeout(&spec).unwrap();
        assert_eq!(out.step, 6);
        assert_eq!(out.epoch, 2);
        assert_eq!(out.victims, vec![1, 3]);
        assert_eq!(out.shard, ShardId { pp: 0, tp: 0, zero: 1 });
        assert!(out.checkpoint_free, "plan must be sourced without checkpoints");
        assert_eq!(
            out.rebuilt_hash, out.warm_spare_hash,
            "network rebuild and warm-spare local rebuild must agree"
        );
        // three passes over a 2+1 code: full push, pure refresh of the
        // unchanged step, full push of the failure step
        assert_eq!(out.stripes_shipped, 6);
        assert_eq!(out.stripes_refreshed, 3);
        assert!(out.wall_s > 0.0);
    }

    #[test]
    fn wipeout_driver_rejects_a_partial_group() {
        // double_fault kills ranks 1 and 2 — rank 3 still holds rank
        // 1's shard, so the wipeout drill must refuse to run
        // dishonestly and leave that case to the replica restore path.
        let spec = library::by_name("double_fault", 256).unwrap();
        let err = drive_replica_group_wipeout(&spec).unwrap_err();
        assert!(
            format!("{err}").contains("not a whole replica group"),
            "unexpected error: {err}"
        );
    }
}
