//! Live execution path: interpret a chaos spec against the *real*
//! in-process training plane (`coordinator::Controller` +
//! `training::worker` threads executing PJRT artifacts).
//!
//! The simulator path scales to paper-size clusters; this path trades
//! scale for realism — actual worker threads, actual collectives,
//! actual state restore. Spec faults map to scripted [`FailurePlan`]s
//! via their live hints (`rank` / `at_step` / `phase`); families with
//! no in-process equivalent (partition, spare exhaustion, straggler)
//! are rejected with a clear error so specs stay honest about what
//! each path can express.
//!
//! Requires compiled artifacts and a real `xla` backend; with the
//! vendored stub `run_live` fails fast and `scenario run` reports the
//! live plane as unavailable (DESIGN.md §7).

use super::engine::AssertionOutcome;
use super::spec::{FaultFamily, ScenarioSpec};
use crate::cluster::failure::FailureKind;
use crate::comms::tcp_store::TcpStoreServer;
use crate::config::ParallelismConfig;
use crate::coordinator::rendezvous::{rebuild_episode, EpisodeConfig, RebuildOutcome};
use crate::coordinator::{ControllerConfig, RankEntry, Ranktable, RunReport};
use crate::training::worker::{FailurePlan, Phase};
use crate::training::TrainingEngine;
use anyhow::{bail, Context, Result};
use std::collections::BTreeMap;

fn parse_phase(s: &str) -> Phase {
    match s {
        "optstep" | "opt" | "optimizer" => Phase::OptStep,
        _ => Phase::FwdBwd,
    }
}

/// Expand the spec's fault timeline into scripted worker failures.
pub fn live_failure_plans(spec: &ScenarioSpec) -> Result<Vec<FailurePlan>> {
    let mut plans = Vec::new();
    for (i, f) in spec.faults.iter().enumerate() {
        let rank = |d: usize| f.rank.unwrap_or(d) % spec.live.dp.max(1);
        let step = f
            .at_step
            .with_context(|| format!("fault {i}: live path needs \"at_step\""))?;
        let kind = f.failure.unwrap_or(FailureKind::Segfault);
        let phase = parse_phase(&f.phase);
        match f.family {
            FaultFamily::Crash => {
                plans.push(FailurePlan { rank: rank(i + 1), step, phase, kind })
            }
            FaultFamily::Cascade => {
                for j in 0..f.nodes {
                    plans.push(FailurePlan {
                        rank: (rank(i + 1) + j) % spec.live.dp.max(1),
                        step: step + j as u64,
                        phase,
                        kind,
                    });
                }
            }
            FaultFamily::Flap => {
                for j in 0..f.times {
                    plans.push(FailurePlan {
                        rank: rank(i + 1),
                        step: step + j as u64 * f.period_steps.max(1),
                        phase,
                        kind,
                    });
                }
            }
            other => bail!(
                "fault {i}: {:?} has no live in-process equivalent — run this \
                 scenario on the simulator path",
                other.name()
            ),
        }
    }
    if plans.iter().any(|p| p.step >= spec.live.steps) {
        bail!(
            "live plan schedules a failure at/after the final step {} — raise \
             live.steps in the spec",
            spec.live.steps
        );
    }
    Ok(plans)
}

/// Controller configuration for the live run of a spec.
pub fn controller_config(spec: &ScenarioSpec, seed: u64) -> Result<ControllerConfig> {
    let mut cfg = ControllerConfig::flash(spec.live.dp, spec.live.steps);
    cfg.seed = seed;
    cfg.failures = live_failure_plans(spec)?;
    Ok(cfg)
}

/// Outcome of a live run: the controller's report plus the spec's
/// assertions evaluated against it.
pub struct LiveOutcome {
    pub report: RunReport,
    pub assertions: Vec<AssertionOutcome>,
}

/// Assertions meaningful on the live path, checked against the report.
pub fn evaluate_live(spec: &ScenarioSpec, report: &RunReport) -> Vec<AssertionOutcome> {
    let a = &spec.assertions;
    let mut out = Vec::new();
    let mut check = |name: &str, pass: bool, detail: String| {
        out.push(AssertionOutcome { name: name.to_string(), pass, detail });
    };
    let lost: u64 = report.recoveries.iter().map(|r| r.lost_steps).sum();
    if let Some(bound) = a.max_lost_steps {
        check("max_lost_steps", lost <= bound, format!("{lost} vs bound {bound}"));
    }
    if a.require_all_recovered {
        check(
            "require_all_recovered",
            report.final_step == spec.live.steps,
            format!("final step {} of {}", report.final_step, spec.live.steps),
        );
        check(
            "dp_replicas_bitwise_consistent",
            report.final_param_divergence == 0.0,
            format!("divergence {}", report.final_param_divergence),
        );
    }
    if let Some(min) = a.min_recoveries {
        check(
            "min_recoveries",
            report.recoveries.len() >= min,
            format!("{} vs min {min}", report.recoveries.len()),
        );
    }
    out
}

/// Drive the spec's scripted failures as *real* group-rebuild episodes
/// over a live TCP store: one epoch-fenced rendezvous per failure
/// step, with surviving ranks re-keying (O(1) messages each) and the
/// failed ranks performing full replacement joins. Exercises the
/// reconstruction protocol under chaos campaigns without requiring
/// the xla training plane.
pub fn drive_group_rebuilds(spec: &ScenarioSpec) -> Result<Vec<RebuildOutcome>> {
    let plans = live_failure_plans(spec)?;
    let dp = spec.live.dp.max(1);
    let par = ParallelismConfig::dp(dp);
    let mut table = Ranktable::new(
        (0..dp)
            .map(|rank| RankEntry {
                rank,
                node: rank,
                device: 0,
                addr: format!("127.0.0.1:{}", 29000 + rank),
            })
            .collect(),
    );
    let server = TcpStoreServer::start()?;
    // one rebuild episode per distinct failure step
    let mut by_step: BTreeMap<u64, Vec<usize>> = BTreeMap::new();
    for p in &plans {
        let ranks = by_step.entry(p.step).or_default();
        if !ranks.contains(&p.rank) {
            ranks.push(p.rank);
        }
    }
    let mut epoch = 0u64;
    let mut episodes = Vec::with_capacity(by_step.len());
    for (step, mut failed) in by_step {
        failed.sort_unstable();
        let replacements: Vec<RankEntry> = failed
            .iter()
            .map(|&r| RankEntry {
                rank: r,
                node: dp + (epoch as usize + 1) * dp + r,
                device: 0,
                addr: format!("127.0.0.1:{}", 31000 + step as usize + r),
            })
            .collect();
        let out = rebuild_episode(
            &server,
            &table,
            &par,
            &failed,
            &replacements,
            epoch,
            &EpisodeConfig { live_survivors: dp },
        )?;
        epoch = out.epoch;
        table = out.table.clone();
        episodes.push(out);
    }
    Ok(episodes)
}

/// Run the spec's live plan end to end. Fails fast when the live
/// training plane (real xla + artifacts) is unavailable.
pub fn run_live(spec: &ScenarioSpec, seed: u64) -> Result<LiveOutcome> {
    let cfg = controller_config(spec, seed)?;
    let engine = TrainingEngine::load("tiny")
        .context("live training plane unavailable (needs artifacts + real xla)")?;
    let report = engine.run(cfg)?;
    let assertions = evaluate_live(spec, &report);
    Ok(LiveOutcome { report, assertions })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::library;

    #[test]
    fn single_fault_maps_to_one_plan() {
        let spec = library::by_name("single_fault", 256).unwrap();
        let plans = live_failure_plans(&spec).unwrap();
        assert_eq!(plans.len(), 1);
        assert_eq!(plans[0].rank, 1);
        assert_eq!(plans[0].step, 4);
        let cfg = controller_config(&spec, 3).unwrap();
        assert_eq!(cfg.seed, 3);
        assert_eq!(cfg.failures.len(), 1);
    }

    #[test]
    fn flap_expands_to_spaced_plans_on_one_rank() {
        let spec = library::by_name("flaky_node", 256).unwrap();
        let plans = live_failure_plans(&spec).unwrap();
        assert_eq!(plans.len(), 3);
        assert!(plans.iter().all(|p| p.rank == plans[0].rank));
        assert_eq!(plans[1].step - plans[0].step, 4);
        assert!(plans.last().unwrap().step < spec.live.steps);
    }

    #[test]
    fn unsupported_families_are_rejected() {
        let spec = library::by_name("spare_exhaustion", 256).unwrap();
        assert!(live_failure_plans(&spec).is_err());
        let spec = library::by_name("straggler_degrade", 256).unwrap();
        assert!(live_failure_plans(&spec).is_err());
    }

    #[test]
    fn missing_at_step_is_an_error() {
        let spec = library::by_name("rolling_cascade", 256).unwrap();
        // cascade spec carries no live hints on purpose
        assert!(live_failure_plans(&spec).is_err());
    }

    #[test]
    fn live_bridge_drives_real_group_rebuild() {
        // End to end over real sockets: one failure -> one epoch-fenced
        // rendezvous in which survivors re-key and the failed rank's
        // replacement fully joins.
        let spec = library::by_name("single_fault", 256).unwrap();
        let episodes = drive_group_rebuilds(&spec).unwrap();
        assert_eq!(episodes.len(), 1);
        let ep = &episodes[0];
        assert_eq!(ep.epoch, 1);
        assert_eq!(ep.replacements, 1);
        assert!(ep.groups_rebuilt >= 1);
        assert_eq!(ep.survivor_ops_max, 3, "survivors must stay O(1) msgs");
        assert_eq!(ep.table.version, 2);
        assert!(ep.wall_s > 0.0);
    }

    #[test]
    fn live_bridge_flap_rebuilds_per_episode() {
        // flaky_node kills the same rank three times at spaced steps:
        // three rendezvous epochs, version advancing each time.
        let spec = library::by_name("flaky_node", 256).unwrap();
        let episodes = drive_group_rebuilds(&spec).unwrap();
        assert_eq!(episodes.len(), 3);
        for (i, ep) in episodes.iter().enumerate() {
            assert_eq!(ep.epoch, i as u64 + 1);
            assert_eq!(ep.replacements, 1);
        }
        assert_eq!(episodes.last().unwrap().table.version, 4);
    }
}
