//! Seed-stamped campaign event journal.
//!
//! Every campaign run appends typed events (fault injections,
//! detections, recovery phases, substitutions, rejoin/exhaustion) to a
//! journal that renders to canonical JSONL. The determinism contract:
//! identical `(spec, seed)` pairs produce **byte-identical** journals —
//! every event is keyed by simulated time (never wall clock), object
//! keys render in sorted order (`util::Json` uses a `BTreeMap`), and
//! all randomness flows from the run's seeded RNG in event order.
//! `rust/tests/prop_chaos.rs` enforces the contract.

use crate::util::Json;

/// Append-only event journal for one campaign run.
#[derive(Debug, Clone)]
pub struct Journal {
    pub spec_name: String,
    pub spec_hash: u64,
    pub seed: u64,
    events: Vec<Json>,
    seq: u64,
}

impl Journal {
    pub fn new(spec_name: &str, spec_hash: u64, seed: u64) -> Self {
        Journal {
            spec_name: spec_name.to_string(),
            spec_hash,
            seed,
            events: Vec::new(),
            seq: 0,
        }
    }

    /// Record an event at simulated time `t`. `attrs` must be an
    /// object; `seq`/`t`/`event` keys are stamped on top.
    pub fn push(&mut self, t: f64, event: &str, mut attrs: Json) {
        self.seq += 1;
        attrs
            .set("seq", self.seq)
            .set("t", t)
            .set("event", event);
        self.events.push(attrs);
    }

    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    pub fn events(&self) -> &[Json] {
        &self.events
    }

    /// Canonical JSONL: one header line (spec identity + seed) followed
    /// by one compact JSON object per event. This string is the
    /// byte-identity the determinism tests compare.
    pub fn render(&self) -> String {
        let mut header = Json::object();
        header
            .set("journal", "flashrecovery-chaos-v1")
            .set("scenario", self.spec_name.as_str())
            .set("spec_hash", format!("{:016x}", self.spec_hash))
            .set("seed", self.seed);
        let mut out = header.render();
        out.push('\n');
        for e in &self.events {
            out.push_str(&e.render());
            out.push('\n');
        }
        out
    }

    /// FNV-1a digest of the rendered journal (cheap equality probe).
    pub fn digest(&self) -> u64 {
        crate::util::fnv1a(self.render().as_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_are_sequenced_and_stamped() {
        let mut j = Journal::new("demo", 0xABCD, 7);
        let mut a = Json::object();
        a.set("node", 3usize);
        j.push(12.5, "fault_injected", a);
        j.push(13.0, "detection", Json::object());
        assert_eq!(j.len(), 2);
        let text = j.render();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let head = Json::parse(lines[0]).unwrap();
        assert_eq!(head.get("scenario").as_str(), Some("demo"));
        assert_eq!(head.get("seed").as_i64(), Some(7));
        let e1 = Json::parse(lines[1]).unwrap();
        assert_eq!(e1.get("seq").as_i64(), Some(1));
        assert_eq!(e1.get("event").as_str(), Some("fault_injected"));
        assert_eq!(e1.get("node").as_usize(), Some(3));
    }

    #[test]
    fn identical_pushes_render_identically() {
        let build = || {
            let mut j = Journal::new("x", 1, 2);
            for i in 0..10 {
                let mut a = Json::object();
                a.set("i", i as u64).set("v", i as f64 * 0.1);
                j.push(i as f64, "tick", a);
            }
            j
        };
        assert_eq!(build().render(), build().render());
        assert_eq!(build().digest(), build().digest());
    }
}
