//! Chaos scenario engine: declarative, multi-failure, deterministically
//! replayable fault campaigns.
//!
//! The paper evaluates exactly one failure per run; production fleets
//! see bursty, heterogeneous incidents — cascading node deaths,
//! flapping hosts, failures striking mid-recovery (ByteDance's robust-
//! training report, Unicron). This subsystem expresses such campaigns
//! as data and replays them deterministically:
//!
//! * [`spec`] — the declarative JSON schema: cluster shape, fault
//!   timeline (crash / cascade / flap / straggler / partition /
//!   spare-exhaustion), and outcome assertions;
//! * [`journal`] — the seed-stamped event journal; identical
//!   `(spec, seed)` pairs produce byte-identical journals;
//! * [`engine`] — the campaign interpreter over the calibrated cluster
//!   simulator (shared protocol math with `cluster::scenario`);
//! * [`library`] — fifteen built-in scenarios from the paper baseline
//!   to compound production patterns, including coordination-plane
//!   failover (store primary / controller crashes mid-recovery) and
//!   impaired-plane campaigns (detection under loss, restore over a
//!   WAN link, rendezvous across a partition heal);
//! * [`live`] — the same specs driven against the real in-process
//!   training plane (controller + worker threads) via scripted
//!   failure plans; specs with a `netem:` section run over degraded
//!   links through the §15 link layer (`drive_netem_*`).
//!
//! CLI: `flashrecovery scenario run --spec <name|file> --seed N`;
//! sweep: `cargo bench --bench chaos_campaigns`; tour:
//! `cargo run --example chaos_tour`. Schema: DESIGN.md.

pub mod engine;
pub mod journal;
pub mod library;
pub mod live;
pub mod spec;

pub use engine::{
    evaluate, passed, run_campaign, AssertionOutcome, CampaignRecovery, CampaignReport,
};
pub use journal::Journal;
pub use live::{
    controller_config, drive_controller_crash_mid_restore, drive_group_rebuilds,
    drive_live_detection, drive_netem_detection, drive_netem_partition_heal,
    drive_netem_restore, drive_replica_group_wipeout, drive_restores,
    drive_restores_under_churn, drive_store_crash_mid_rendezvous, evaluate_live,
    live_failure_plans, run_live, ControllerFailoverOutcome, LiveDetectionOutcome,
    LiveOutcome, LiveRestoreOutcome, NetemDetectionOutcome, NetemPartitionOutcome,
    NetemRestoreOutcome, StoreFailoverOutcome, WipeoutOutcome,
};
pub use spec::{
    Assertions, ClusterShape, FaultFamily, FaultSpec, LiveShape, NetemSpec, NodeLink,
    ScenarioSpec,
};
