//! Deterministic multi-failure campaign interpreter (simulator path).
//!
//! Interprets a [`ScenarioSpec`] against the calibrated cluster model:
//! an explicit time-ordered event queue (ties broken by insertion
//! order, like `cluster::simtime`) drives fault injection, detection,
//! recovery, spare substitution, node rejoin, and straggler handling
//! over a [`SimCluster`], journaling every transition. The protocol
//! costs come from the same primitives the single-shot Tab. II/III
//! scenarios use ([`flash_restart_cost`] / [`vanilla_restart_cost`] /
//! [`sample_detection_s`]), so campaign numbers stay calibrated to the
//! paper.
//!
//! Compound-failure semantics:
//! * a fault striking while a recovery is in flight **merges** into it:
//!   the controller folds the new victim in and re-runs communication
//!   establishment for the union, extending the ready time (the
//!   "failure during recovery" case single-shot scenarios cannot
//!   express);
//! * substitution draws from the spare pool; on exhaustion the victim
//!   stays failed (journaled, surfaced in assertions) instead of
//!   wedging the campaign;
//! * with `rejoin_s` configured, substituted nodes return to the spare
//!   pool after repair — what keeps a flapping host scenario bounded;
//! * in flash mode a straggler whose slowdown crosses the eviction
//!   threshold is treated as a soft failure after a patience window
//!   (degrade-aware recovery); vanilla just trains slowly.
//!
//! Determinism contract: identical `(spec, seed)` → byte-identical
//! journals. All randomness flows through one seeded RNG in event
//! order; no wall clock, no hash-map iteration.

use super::journal::Journal;
use super::spec::{Assertions, FaultFamily, ScenarioSpec};
use crate::cluster::failure::{FailureInjector, FailureKind};
use crate::cluster::{
    flash_restart_cost, sample_detection_s, vanilla_restart_cost, NodeState,
    ScenarioConfig, SimCluster,
};
use crate::config::RecoveryMode;
use crate::util::{Json, Rng};
use anyhow::Result;
use std::cmp::Ordering;
use std::collections::{BinaryHeap, BTreeMap};

/// One completed recovery episode (possibly covering several merged
/// faults).
#[derive(Debug, Clone)]
pub struct CampaignRecovery {
    /// First fault of the episode struck here.
    pub started_s: f64,
    /// Controller became aware (first detection complete).
    pub aware_s: f64,
    pub ended_s: f64,
    pub detection_s: f64,
    /// Aware -> all substitutions done and fleet training again.
    pub restart_s: f64,
    pub nodes: Vec<usize>,
    /// Faults absorbed after the episode had already begun.
    pub merged_faults: usize,
    pub lost_steps: u64,
}

impl CampaignRecovery {
    /// Detection + restart: the per-episode recovery time assertions
    /// bound.
    pub fn total_s(&self) -> f64 {
        self.detection_s + self.restart_s
    }
}

/// Outcome of one campaign run.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub scenario: String,
    pub seed: u64,
    pub mode: RecoveryMode,
    pub recoveries: Vec<CampaignRecovery>,
    pub merged_recoveries: usize,
    pub spare_exhausted: bool,
    pub stragglers_evicted: usize,
    /// Nodes still failed (unsubstituted) at campaign end.
    pub unrecovered_nodes: usize,
    pub steps_completed: u64,
    pub lost_steps: u64,
    pub total_downtime_s: f64,
    pub final_running_nodes: usize,
    pub spares_left: usize,
    pub horizon_s: f64,
    /// Last processed event time (>= horizon when recoveries ran long).
    pub end_s: f64,
    pub step_time_s: f64,
}

impl CampaignReport {
    pub fn to_json(&self) -> Json {
        let mut o = Json::object();
        o.set("scenario", self.scenario.as_str())
            .set("seed", self.seed)
            .set("mode", self.mode.name())
            .set("merged_recoveries", self.merged_recoveries)
            .set("spare_exhausted", self.spare_exhausted)
            .set("stragglers_evicted", self.stragglers_evicted)
            .set("unrecovered_nodes", self.unrecovered_nodes)
            .set("steps_completed", self.steps_completed)
            .set("lost_steps", self.lost_steps)
            .set("total_downtime_s", self.total_downtime_s)
            .set("final_running_nodes", self.final_running_nodes)
            .set("spares_left", self.spares_left)
            .set("end_s", self.end_s)
            .set(
                "recoveries",
                Json::Array(
                    self.recoveries
                        .iter()
                        .map(|r| {
                            let mut e = Json::object();
                            e.set("started_s", r.started_s)
                                .set("ended_s", r.ended_s)
                                .set("detection_s", r.detection_s)
                                .set("restart_s", r.restart_s)
                                .set("total_s", r.total_s())
                                .set(
                                    "nodes",
                                    Json::Array(
                                        r.nodes.iter().map(|n| Json::from(*n)).collect(),
                                    ),
                                )
                                .set("merged_faults", r.merged_faults)
                                .set("lost_steps", r.lost_steps);
                            e
                        })
                        .collect(),
                ),
            );
        o
    }
}

/// One evaluated assertion.
#[derive(Debug, Clone)]
pub struct AssertionOutcome {
    pub name: String,
    pub pass: bool,
    pub detail: String,
}

/// True iff every assertion passed.
pub fn passed(outcomes: &[AssertionOutcome]) -> bool {
    outcomes.iter().all(|o| o.pass)
}

// ---------------------------------------------------------------- queue

#[derive(Debug, Clone)]
enum Ev {
    Fault {
        /// Fault-spec index (flap anchor key).
        spec_idx: usize,
        node: Option<usize>,
        kind: Option<FailureKind>,
        wanted: usize,
        /// Flap occurrences after the first follow the device block.
        follow_anchor: bool,
    },
    RecoveryDone {
        gen: u64,
    },
    Rejoin {
        node: usize,
    },
    StragglerStart {
        node: Option<usize>,
        slowdown: f64,
        duration_s: f64,
    },
    StragglerEnd {
        node: usize,
        token: u64,
    },
    StragglerEvict {
        node: usize,
        token: u64,
    },
    Horizon,
}

struct QEntry {
    at: f64,
    seq: u64,
    ev: Ev,
}

impl PartialEq for QEntry {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl Eq for QEntry {}
impl PartialOrd for QEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for QEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // Max-heap: reverse so earliest (time, seq) pops first.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

// --------------------------------------------------------------- engine

struct InFlight {
    gen: u64,
    first_fault_s: f64,
    aware_s: f64,
    ready_s: f64,
    detection_s: f64,
    nodes: Vec<usize>,
    merged_faults: usize,
    lost_steps: u64,
}

struct Campaign<'a> {
    spec: &'a ScenarioSpec,
    scfg: ScenarioConfig,
    rng: Rng,
    cluster: SimCluster,
    queue: BinaryHeap<QEntry>,
    seq: u64,
    last_t: f64,
    steps_accum: f64,
    downtime_s: f64,
    lost_steps: u64,
    recovery: Option<InFlight>,
    gen: u64,
    /// node -> (slow factor, token); job step time scales by the max.
    slow: BTreeMap<usize, (f64, u64)>,
    slow_token: u64,
    flap_anchor: BTreeMap<usize, usize>,
    recoveries: Vec<CampaignRecovery>,
    merged_recoveries: usize,
    spare_exhausted: bool,
    stragglers_evicted: usize,
    step_time_s: f64,
    journal: Journal,
}

impl<'a> Campaign<'a> {
    fn new(spec: &'a ScenarioSpec, seed: u64) -> Self {
        let c = &spec.cluster;
        let spec_hash = spec.hash();
        let scfg = ScenarioConfig {
            devices: c.devices,
            devices_per_node: c.devices_per_node,
            model_params: c.model_params,
            lat: Default::default(),
            step: Default::default(),
            heartbeat_interval_s: c.heartbeat_interval_s,
            miss_threshold: c.miss_threshold,
            collective_timeout_s: c.collective_timeout_s,
            tcp_parallelism: c.tcp_parallelism,
            seed,
        };
        let step_time_s = scfg.step.step_time_s(c.model_params, c.devices);
        Campaign {
            spec,
            scfg,
            rng: Rng::new(seed ^ spec_hash),
            cluster: SimCluster::new(c.active_nodes(), c.spare_nodes, c.devices_per_node),
            queue: BinaryHeap::new(),
            seq: 0,
            last_t: 0.0,
            steps_accum: 0.0,
            downtime_s: 0.0,
            lost_steps: 0,
            recovery: None,
            gen: 0,
            slow: BTreeMap::new(),
            slow_token: 0,
            flap_anchor: BTreeMap::new(),
            recoveries: Vec::new(),
            merged_recoveries: 0,
            spare_exhausted: false,
            stragglers_evicted: 0,
            step_time_s,
            journal: Journal::new(&spec.name, spec_hash, seed),
        }
    }

    fn push(&mut self, at: f64, ev: Ev) {
        self.seq += 1;
        self.queue.push(QEntry { at, seq: self.seq, ev });
    }

    /// Expand the declarative fault timeline into primitive events.
    /// Occurrences past the horizon are dropped (deterministically).
    fn expand(&mut self) {
        let horizon = self.spec.horizon_s;
        let spares = self.spec.cluster.spare_nodes;
        let faults = self.spec.faults.clone();
        for (idx, f) in faults.iter().enumerate() {
            match f.family {
                FaultFamily::Crash => self.push(
                    f.at_s,
                    Ev::Fault {
                        spec_idx: idx,
                        node: f.node,
                        kind: f.failure,
                        wanted: 1,
                        follow_anchor: false,
                    },
                ),
                FaultFamily::Cascade => {
                    for i in 0..f.nodes {
                        let at = f.at_s + i as f64 * f.spacing_s;
                        if at <= horizon {
                            self.push(
                                at,
                                Ev::Fault {
                                    spec_idx: idx,
                                    node: if i == 0 { f.node } else { None },
                                    kind: f.failure,
                                    wanted: 1,
                                    follow_anchor: false,
                                },
                            );
                        }
                    }
                }
                FaultFamily::Partition => self.push(
                    f.at_s,
                    Ev::Fault {
                        spec_idx: idx,
                        node: f.node,
                        kind: f.failure.or(Some(FailureKind::Network)),
                        wanted: f.nodes,
                        follow_anchor: false,
                    },
                ),
                FaultFamily::SpareExhaustion => self.push(
                    f.at_s,
                    Ev::Fault {
                        spec_idx: idx,
                        node: f.node,
                        kind: f.failure,
                        wanted: (spares + 1).min(self.spec.cluster.active_nodes()),
                        follow_anchor: false,
                    },
                ),
                FaultFamily::Flap => {
                    for i in 0..f.times {
                        let at = f.at_s + i as f64 * f.period_s;
                        if at <= horizon {
                            self.push(
                                at,
                                Ev::Fault {
                                    spec_idx: idx,
                                    node: if i == 0 { f.node } else { None },
                                    kind: f.failure,
                                    wanted: 1,
                                    follow_anchor: i > 0,
                                },
                            );
                        }
                    }
                }
                FaultFamily::Straggler => self.push(
                    f.at_s,
                    Ev::StragglerStart {
                        node: f.node,
                        slowdown: f.slowdown,
                        duration_s: f.duration_s,
                    },
                ),
            }
        }
        self.push(horizon, Ev::Horizon);
    }

    /// Job-wide step-time multiplier (synchronous DP: the slowest
    /// member paces everyone).
    fn slow_factor(&self) -> f64 {
        self.slow
            .values()
            .map(|(f, _)| *f)
            .fold(1.0, f64::max)
    }

    /// Advance training/downtime accounting to `t`.
    fn advance(&mut self, t: f64) {
        let dt = t - self.last_t;
        if dt <= 0.0 {
            return;
        }
        if self.recovery.is_some() {
            self.downtime_s += dt;
        } else {
            self.steps_accum += dt / (self.step_time_s * self.slow_factor());
        }
        self.last_t = t;
    }

    fn running_nodes(&self) -> Vec<usize> {
        self.cluster
            .nodes
            .iter()
            .filter(|n| n.state == NodeState::Running)
            .map(|n| n.id)
            .collect()
    }

    /// Pick `wanted` distinct running victims; explicit/anchored
    /// choices first, the rest sampled uniformly.
    fn pick_victims(
        &mut self,
        spec_idx: usize,
        node: Option<usize>,
        wanted: usize,
        follow_anchor: bool,
    ) -> Vec<usize> {
        let mut pool = self.running_nodes();
        let mut victims = Vec::new();

        if follow_anchor {
            // Flap re-occurrence: hit whichever node now hosts the
            // anchored device block (the logical rank keeps dying even
            // though the physical substitute changed). If the holder is
            // not currently running (still mid-recovery), this
            // occurrence fizzles rather than retargeting a random node.
            match self.flap_anchor.get(&spec_idx).copied().and_then(|device| {
                self.cluster.node_of_device(device)
            }) {
                Some(holder) => {
                    if let Some(pos) = pool.iter().position(|&n| n == holder) {
                        pool.swap_remove(pos);
                        victims.push(holder);
                    } else {
                        return Vec::new();
                    }
                }
                None => return Vec::new(),
            }
        } else if let Some(n) = node {
            if let Some(pos) = pool.iter().position(|&p| p == n) {
                pool.swap_remove(pos);
                victims.push(n);
            }
        }

        while victims.len() < wanted && !pool.is_empty() {
            // Sorted pool + seeded draw keeps selection deterministic.
            pool.sort_unstable();
            let i = self.rng.below(pool.len() as u64) as usize;
            victims.push(pool.swap_remove(i));
        }
        victims.sort_unstable();
        victims
    }

    fn on_fault(
        &mut self,
        t: f64,
        spec_idx: usize,
        node: Option<usize>,
        kind: Option<FailureKind>,
        wanted: usize,
        follow_anchor: bool,
    ) {
        let victims = self.pick_victims(spec_idx, node, wanted, follow_anchor);
        if victims.is_empty() {
            self.journal.push(t, "fault_dropped_no_target", Json::object());
            return;
        }
        // Anchor the first flap occurrence to the victim's device block.
        if !follow_anchor {
            if let Some(first_dev) =
                self.cluster.nodes[victims[0]].devices.first().copied()
            {
                self.flap_anchor.entry(spec_idx).or_insert(first_dev);
            }
        }
        let kind = kind.unwrap_or_else(|| FailureInjector::sample_kind(&mut self.rng));
        for &v in &victims {
            self.cluster.fail_node(v).expect("victim was running");
            // A failed straggler is no longer pacing the job.
            self.slow.remove(&v);
            let mut a = Json::object();
            a.set("node", v).set("kind", kind.name());
            self.journal.push(t, "fault_injected", a);
        }

        let detection_s = match self.spec.mode {
            RecoveryMode::Flash => sample_detection_s(&self.scfg, kind, &mut self.rng),
            RecoveryMode::Vanilla => self.scfg.collective_timeout_s,
        };
        let aware = t + detection_s;
        let cost_for = |me: &mut Self, k: usize| match me.spec.mode {
            RecoveryMode::Flash => flash_restart_cost(&me.scfg, k, &mut me.rng),
            RecoveryMode::Vanilla => vanilla_restart_cost(&me.scfg, &mut me.rng),
        };

        match self.recovery.take() {
            Some(mut rec) => {
                // Failure during recovery: fold the new victims in and
                // re-establish for the union — the ready time extends.
                rec.nodes.extend(victims.iter().copied());
                rec.merged_faults += 1;
                let cost = cost_for(self, rec.nodes.len());
                let extended = (aware + cost.critical_path_s).max(rec.ready_s);
                let mut a = Json::object();
                a.set("pending_nodes", rec.nodes.len())
                    .set("ready_s", extended);
                self.journal.push(t, "recovery_extended", a);
                rec.ready_s = extended;
                self.gen += 1;
                rec.gen = self.gen;
                self.push(extended, Ev::RecoveryDone { gen: self.gen });
                self.recovery = Some(rec);
                self.merged_recoveries += 1;
            }
            None => {
                let lost = match self.spec.mode {
                    RecoveryMode::Flash => 0,
                    // Vanilla rolls back to the last periodic checkpoint.
                    RecoveryMode::Vanilla => {
                        let done = self.steps_accum.floor() as u64;
                        done % self.spec.cluster.ckpt_interval_steps.max(1)
                    }
                };
                let cost = cost_for(self, victims.len());
                let ready = aware + cost.critical_path_s;
                let mut a = Json::object();
                a.set("detection_s", detection_s)
                    .set("nodes", victims.len())
                    .set("ready_s", ready);
                self.journal.push(t, "recovery_started", a);
                self.gen += 1;
                self.push(ready, Ev::RecoveryDone { gen: self.gen });
                self.recovery = Some(InFlight {
                    gen: self.gen,
                    first_fault_s: t,
                    aware_s: aware,
                    ready_s: ready,
                    detection_s,
                    nodes: victims,
                    merged_faults: 0,
                    lost_steps: lost,
                });
            }
        }
    }

    fn on_recovery_done(&mut self, t: f64, gen: u64) {
        if self.recovery.as_ref().map(|r| r.gen) != Some(gen) {
            return; // superseded by a merged extension
        }
        let rec = self.recovery.take().unwrap();
        for &node in &rec.nodes {
            match self.cluster.substitute(node) {
                Ok(spare) => {
                    let mut a = Json::object();
                    a.set("node", node).set("spare", spare);
                    self.journal.push(t, "node_substituted", a);
                    if let Some(rejoin) = self.spec.cluster.rejoin_s {
                        self.push(t + rejoin, Ev::Rejoin { node });
                    }
                }
                Err(_) => {
                    self.spare_exhausted = true;
                    let mut a = Json::object();
                    a.set("node", node);
                    self.journal.push(t, "spare_pool_exhausted", a);
                    if let Some(rejoin) = self.spec.cluster.rejoin_s {
                        self.push(t + rejoin, Ev::Rejoin { node });
                    }
                }
            }
        }
        for id in 0..self.cluster.nodes.len() {
            if self.cluster.nodes[id].state == NodeState::Starting {
                self.cluster.set_state(id, NodeState::Running);
            }
        }
        // FlashRecovery redoes the interrupted half step on resume.
        if self.spec.mode == RecoveryMode::Flash {
            self.downtime_s += self.step_time_s / 2.0;
        }
        self.lost_steps += rec.lost_steps;
        let mut a = Json::object();
        a.set("nodes", rec.nodes.len())
            .set("restart_s", t - rec.aware_s)
            .set("downtime_s", t - rec.first_fault_s)
            .set("merged_faults", rec.merged_faults);
        self.journal.push(t, "recovery_complete", a);
        self.recoveries.push(CampaignRecovery {
            started_s: rec.first_fault_s,
            aware_s: rec.aware_s,
            ended_s: t,
            detection_s: rec.detection_s,
            restart_s: t - rec.aware_s,
            nodes: rec.nodes,
            merged_faults: rec.merged_faults,
            lost_steps: rec.lost_steps,
        });
    }

    fn on_rejoin(&mut self, t: f64, node: usize) {
        if self.cluster.nodes[node].state != NodeState::Faulty {
            return;
        }
        if self.cluster.nodes[node].devices.is_empty() {
            // Substituted earlier: repaired machine re-enters the pool.
            self.cluster.set_state(node, NodeState::Spare);
            let mut a = Json::object();
            a.set("node", node);
            self.journal.push(t, "node_rejoined_as_spare", a);
        } else {
            // Never substituted (pool was exhausted): repaired in place
            // and resumes serving its own device block.
            self.cluster.set_state(node, NodeState::Running);
            let mut a = Json::object();
            a.set("node", node);
            self.journal.push(t, "node_repaired_in_place", a);
        }
    }

    fn on_straggler_start(
        &mut self,
        t: f64,
        node: Option<usize>,
        slowdown: f64,
        duration_s: f64,
    ) {
        let victims = self.pick_victims(usize::MAX, node, 1, false);
        let Some(&v) = victims.first() else {
            self.journal.push(t, "fault_dropped_no_target", Json::object());
            return;
        };
        self.slow_token += 1;
        let token = self.slow_token;
        self.slow.insert(v, (slowdown, token));
        let mut a = Json::object();
        a.set("node", v).set("slowdown", slowdown);
        self.journal.push(t, "straggler_start", a);
        let c = &self.spec.cluster;
        if self.spec.mode == RecoveryMode::Flash
            && slowdown >= c.straggler_evict_threshold
        {
            self.push(
                t + c.straggler_evict_after_s,
                Ev::StragglerEvict { node: v, token },
            );
        }
        self.push(t + duration_s, Ev::StragglerEnd { node: v, token });
    }

    fn on_straggler_end(&mut self, t: f64, node: usize, token: u64) {
        if self.slow.get(&node).map(|(_, tok)| *tok) != Some(token) {
            return;
        }
        self.slow.remove(&node);
        let mut a = Json::object();
        a.set("node", node);
        self.journal.push(t, "straggler_end", a);
    }

    fn on_straggler_evict(&mut self, t: f64, node: usize, token: u64) {
        if self.slow.get(&node).map(|(_, tok)| *tok) != Some(token) {
            return;
        }
        self.slow.remove(&node);
        self.stragglers_evicted += 1;
        let mut a = Json::object();
        a.set("node", node);
        self.journal.push(t, "straggler_evicted", a);
        // Eviction is a controller-initiated soft failure: the degraded
        // node is replaced like a timed-out one.
        self.on_fault(
            t,
            usize::MAX - 1,
            Some(node),
            Some(FailureKind::Timeout),
            1,
            false,
        );
    }

    fn run(mut self) -> (CampaignReport, Journal) {
        {
            let mut a = Json::object();
            a.set("mode", self.spec.mode.name())
                .set("nodes", self.spec.cluster.active_nodes())
                .set("spares", self.spec.cluster.spare_nodes)
                .set("devices", self.spec.cluster.devices)
                .set("step_time_s", self.step_time_s);
            self.journal.push(0.0, "campaign_start", a);
        }
        self.expand();
        while let Some(QEntry { at, ev, .. }) = self.queue.pop() {
            self.advance(at);
            match ev {
                Ev::Fault { spec_idx, node, kind, wanted, follow_anchor } => {
                    self.on_fault(at, spec_idx, node, kind, wanted, follow_anchor)
                }
                Ev::RecoveryDone { gen } => self.on_recovery_done(at, gen),
                Ev::Rejoin { node } => self.on_rejoin(at, node),
                Ev::StragglerStart { node, slowdown, duration_s } => {
                    self.on_straggler_start(at, node, slowdown, duration_s)
                }
                Ev::StragglerEnd { node, token } => {
                    self.on_straggler_end(at, node, token)
                }
                Ev::StragglerEvict { node, token } => {
                    self.on_straggler_evict(at, node, token)
                }
                Ev::Horizon => {}
            }
        }
        let end_s = self.last_t;
        let steps_completed =
            (self.steps_accum.floor() as u64).saturating_sub(self.lost_steps);
        let report = CampaignReport {
            scenario: self.spec.name.clone(),
            seed: self.journal.seed,
            mode: self.spec.mode,
            merged_recoveries: self.merged_recoveries,
            spare_exhausted: self.spare_exhausted,
            stragglers_evicted: self.stragglers_evicted,
            unrecovered_nodes: self.cluster.count(NodeState::Faulty),
            steps_completed,
            lost_steps: self.lost_steps,
            total_downtime_s: self.downtime_s,
            final_running_nodes: self.cluster.count(NodeState::Running),
            spares_left: self.cluster.count(NodeState::Spare),
            horizon_s: self.spec.horizon_s,
            end_s,
            step_time_s: self.step_time_s,
            recoveries: self.recoveries,
        };
        // journal tail carries the summary for offline scraping
        self.journal.push(end_s, "campaign_end", report.to_json());
        (report, self.journal)
    }
}

/// Run one campaign: interpret `spec` under `seed`, returning the
/// report and the replayable event journal.
pub fn run_campaign(spec: &ScenarioSpec, seed: u64) -> Result<(CampaignReport, Journal)> {
    spec.validate()?;
    Ok(Campaign::new(spec, seed).run())
}

/// Evaluate a spec's assertions against a campaign report.
pub fn evaluate(assertions: &Assertions, report: &CampaignReport) -> Vec<AssertionOutcome> {
    let mut out = Vec::new();
    let mut check = |name: &str, pass: bool, detail: String| {
        out.push(AssertionOutcome { name: name.to_string(), pass, detail });
    };

    if let Some(bound) = assertions.max_single_recovery_s {
        let worst = report
            .recoveries
            .iter()
            .map(|r| r.total_s())
            .fold(0.0f64, f64::max);
        check(
            "max_single_recovery_s",
            worst <= bound,
            format!("worst {worst:.1}s vs bound {bound:.1}s"),
        );
    }
    if let Some(bound) = assertions.max_total_downtime_s {
        check(
            "max_total_downtime_s",
            report.total_downtime_s <= bound,
            format!("{:.1}s vs bound {bound:.1}s", report.total_downtime_s),
        );
    }
    if let Some(bound) = assertions.max_lost_steps {
        check(
            "max_lost_steps",
            report.lost_steps <= bound,
            format!("{} vs bound {bound}", report.lost_steps),
        );
    }
    if assertions.require_all_recovered {
        check(
            "require_all_recovered",
            report.unrecovered_nodes == 0,
            format!("{} nodes unrecovered", report.unrecovered_nodes),
        );
    }
    if let Some(min) = assertions.min_recoveries {
        check(
            "min_recoveries",
            report.recoveries.len() >= min,
            format!("{} vs min {min}", report.recoveries.len()),
        );
    }
    if let Some(min) = assertions.min_merged_recoveries {
        check(
            "min_merged_recoveries",
            report.merged_recoveries >= min,
            format!("{} vs min {min}", report.merged_recoveries),
        );
    }
    check(
        "spare_exhaustion",
        report.spare_exhausted == assertions.expect_spare_exhaustion,
        format!(
            "exhausted={} expected={}",
            report.spare_exhausted, assertions.expect_spare_exhaustion
        ),
    );
    if let Some(min) = assertions.min_steps_completed {
        check(
            "min_steps_completed",
            report.steps_completed >= min,
            format!("{} vs min {min}", report.steps_completed),
        );
    }
    if let Some(min) = assertions.min_final_running_nodes {
        check(
            "min_final_running_nodes",
            report.final_running_nodes >= min,
            format!("{} vs min {min}", report.final_running_nodes),
        );
    }
    if let Some(min) = assertions.min_stragglers_evicted {
        check(
            "min_stragglers_evicted",
            report.stragglers_evicted >= min,
            format!("{} vs min {min}", report.stragglers_evicted),
        );
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::library;

    #[test]
    fn all_library_scenarios_pass_their_assertions() {
        for spec in library::all(256) {
            for seed in [1u64, 7, 42] {
                let (report, _) = run_campaign(&spec, seed).unwrap();
                let outcomes = evaluate(&spec.assertions, &report);
                assert!(
                    passed(&outcomes),
                    "{} seed {seed} failed: {:?}",
                    spec.name,
                    outcomes.iter().filter(|o| !o.pass).collect::<Vec<_>>()
                );
            }
        }
    }

    #[test]
    fn same_spec_and_seed_give_byte_identical_journals() {
        let spec = library::by_name("rolling_cascade", 256).unwrap();
        let (_, j1) = run_campaign(&spec, 9).unwrap();
        let (_, j2) = run_campaign(&spec, 9).unwrap();
        assert_eq!(j1.render(), j2.render());
    }

    #[test]
    fn different_seeds_diverge() {
        let spec = library::by_name("single_fault", 256).unwrap();
        let (_, j1) = run_campaign(&spec, 1).unwrap();
        let (_, j2) = run_campaign(&spec, 2).unwrap();
        assert_ne!(j1.render(), j2.render());
    }

    #[test]
    fn failure_during_recovery_merges() {
        let spec = library::by_name("failure_during_recovery", 256).unwrap();
        let (report, journal) = run_campaign(&spec, 3).unwrap();
        assert!(report.merged_recoveries >= 1);
        assert_eq!(report.recoveries.len(), 1, "one merged episode expected");
        assert_eq!(report.recoveries[0].nodes.len(), 2);
        assert!(journal
            .events()
            .iter()
            .any(|e| e.get("event").as_str() == Some("recovery_extended")));
    }

    #[test]
    fn spare_exhaustion_degrades_without_wedging() {
        let spec = library::by_name("spare_exhaustion", 256).unwrap();
        let (report, _) = run_campaign(&spec, 5).unwrap();
        assert!(report.spare_exhausted);
        assert_eq!(report.unrecovered_nodes, 1);
        assert_eq!(report.spares_left, 0);
        // job keeps training on the surviving fleet
        assert!(report.steps_completed > 0);
    }

    #[test]
    fn flap_keeps_hitting_the_same_device_block() {
        let spec = library::by_name("flaky_node", 256).unwrap();
        let (report, journal) = run_campaign(&spec, 11).unwrap();
        assert!(report.recoveries.len() >= 3, "{}", report.recoveries.len());
        // every substitution must eventually be matched by a rejoin
        let subs = journal
            .events()
            .iter()
            .filter(|e| e.get("event").as_str() == Some("node_substituted"))
            .count();
        let rejoins = journal
            .events()
            .iter()
            .filter(|e| e.get("event").as_str() == Some("node_rejoined_as_spare"))
            .count();
        assert!(subs >= 3);
        assert!(rejoins >= subs - 1, "{rejoins} rejoins for {subs} subs");
    }

    #[test]
    fn vanilla_campaign_loses_steps_and_detects_slowly() {
        let mut spec = library::by_name("single_fault", 256).unwrap();
        spec.mode = RecoveryMode::Vanilla;
        spec.cluster.collective_timeout_s = 300.0;
        spec.horizon_s = 3600.0;
        spec.assertions = Default::default();
        spec.assertions.require_all_recovered = true;
        let (report, _) = run_campaign(&spec, 2).unwrap();
        assert_eq!(report.recoveries.len(), 1);
        assert!(report.recoveries[0].detection_s >= 300.0);
        // fault at 120s: a handful of steps were done and rolled back
        assert!(report.lost_steps > 0, "expected checkpoint rollback loss");
    }
}
