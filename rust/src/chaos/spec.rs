//! Declarative chaos scenario specs: fault campaigns as data.
//!
//! A [`ScenarioSpec`] declares a cluster shape, a timeline of faults
//! (crash, cascade, flap, straggler-degrade, network partition,
//! spare-pool exhaustion), and assertions on the campaign outcome (max
//! recovery time, max lost steps, final cluster health). Specs load
//! from JSON via the repo's own `util::json` machinery — no serde —
//! and render back canonically, so a spec's identity (and the
//! determinism contract of the engine) is `(spec hash, seed)`.
//!
//! See DESIGN.md §"Chaos scenario spec schema" for the full schema and
//! a worked example.

use crate::cluster::failure::FailureKind;
use crate::comms::netem::{LinkPolicy, Partition};
use crate::config::RecoveryMode;
use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::Path;

/// Spec-level fault families. `Crash`/`Cascade`/`Flap`/`Partition`
/// remove nodes; `Straggler` degrades one; `SpareExhaustion` is sugar
/// for "crash one more node than the spare pool can absorb, at once".
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultFamily {
    Crash,
    Cascade,
    Flap,
    Straggler,
    Partition,
    SpareExhaustion,
}

impl FaultFamily {
    pub fn name(&self) -> &'static str {
        match self {
            FaultFamily::Crash => "crash",
            FaultFamily::Cascade => "cascade",
            FaultFamily::Flap => "flap",
            FaultFamily::Straggler => "straggler",
            FaultFamily::Partition => "partition",
            FaultFamily::SpareExhaustion => "spare_exhaustion",
        }
    }

    pub fn parse(s: &str) -> Result<Self> {
        Ok(match s {
            "crash" => FaultFamily::Crash,
            "cascade" => FaultFamily::Cascade,
            "flap" => FaultFamily::Flap,
            "straggler" => FaultFamily::Straggler,
            "partition" => FaultFamily::Partition,
            "spare_exhaustion" => FaultFamily::SpareExhaustion,
            other => bail!("unknown fault kind {other:?}"),
        })
    }
}

/// One entry in the fault timeline.
#[derive(Debug, Clone)]
pub struct FaultSpec {
    pub family: FaultFamily,
    /// Injection time (simulated seconds from campaign start).
    pub at_s: f64,
    /// Victim node (engine picks a running node when `None`).
    pub node: Option<usize>,
    /// Victim count (cascade length / partition width).
    pub nodes: usize,
    /// Seconds between cascade members.
    pub spacing_s: f64,
    /// Flap repetitions and period.
    pub times: usize,
    pub period_s: f64,
    /// Straggler step-time multiplier and duration.
    pub slowdown: f64,
    pub duration_s: f64,
    /// Concrete failure kind presented to detection (sampled from the
    /// Fig. 9 mix when `None`).
    pub failure: Option<FailureKind>,
    /// Live-path hints (in-process controller run): which DP rank dies
    /// at which optimizer step, in which phase ("fwdbwd"/"optstep").
    pub rank: Option<usize>,
    pub at_step: Option<u64>,
    pub period_steps: u64,
    pub phase: String,
}

impl Default for FaultSpec {
    fn default() -> Self {
        FaultSpec {
            family: FaultFamily::Crash,
            at_s: 0.0,
            node: None,
            nodes: 1,
            spacing_s: 30.0,
            times: 3,
            period_s: 300.0,
            slowdown: 3.0,
            duration_s: 300.0,
            failure: None,
            rank: None,
            at_step: None,
            period_steps: 4,
            phase: "fwdbwd".to_string(),
        }
    }
}

/// Cluster shape + control-plane constants for a campaign.
#[derive(Debug, Clone)]
pub struct ClusterShape {
    pub devices: usize,
    pub devices_per_node: usize,
    pub spare_nodes: usize,
    pub model_params: f64,
    pub tcp_parallelism: usize,
    pub heartbeat_interval_s: f64,
    pub miss_threshold: u32,
    pub collective_timeout_s: f64,
    /// Failed nodes rejoin the spare pool this long after substitution
    /// (repair + health check); `None` = never (default).
    pub rejoin_s: Option<f64>,
    /// Vanilla-mode checkpoint interval in steps (lost-work accounting).
    pub ckpt_interval_steps: u64,
    /// Flash evicts a straggler whose slowdown meets the threshold
    /// after this much patience.
    pub straggler_evict_after_s: f64,
    pub straggler_evict_threshold: f64,
}

impl Default for ClusterShape {
    fn default() -> Self {
        ClusterShape {
            devices: 256,
            devices_per_node: 8,
            spare_nodes: 1,
            model_params: 7e9,
            tcp_parallelism: 64,
            heartbeat_interval_s: 2.0,
            miss_threshold: 3,
            collective_timeout_s: 1800.0,
            rejoin_s: None,
            ckpt_interval_steps: 100,
            straggler_evict_after_s: 30.0,
            straggler_evict_threshold: 2.0,
        }
    }
}

impl ClusterShape {
    pub fn active_nodes(&self) -> usize {
        self.devices.div_ceil(self.devices_per_node)
    }
}

/// Pass/fail conditions evaluated against the campaign report.
#[derive(Debug, Clone)]
pub struct Assertions {
    /// Every individual recovery (detection + restart) within bound.
    pub max_single_recovery_s: Option<f64>,
    /// Total time the job spent not training.
    pub max_total_downtime_s: Option<f64>,
    /// Total completed optimizer steps discarded by rollbacks.
    pub max_lost_steps: Option<u64>,
    /// Every failed node must be substituted by campaign end.
    pub require_all_recovered: bool,
    pub min_recoveries: Option<usize>,
    /// Recoveries that absorbed a fault striking mid-recovery.
    pub min_merged_recoveries: Option<usize>,
    pub expect_spare_exhaustion: bool,
    pub min_steps_completed: Option<u64>,
    pub min_final_running_nodes: Option<usize>,
    pub min_stragglers_evicted: Option<usize>,
}

impl Default for Assertions {
    fn default() -> Self {
        Assertions {
            max_single_recovery_s: None,
            max_total_downtime_s: None,
            max_lost_steps: None,
            require_all_recovered: true,
            min_recoveries: None,
            min_merged_recoveries: None,
            expect_spare_exhaustion: false,
            min_steps_completed: None,
            min_final_running_nodes: None,
            min_stragglers_evicted: None,
        }
    }
}

/// One per-rank link override in a [`NetemSpec`].
#[derive(Debug, Clone)]
pub struct NodeLink {
    /// Live DP rank whose link is impaired; `None` impairs the link
    /// every rank shares (the coordination-plane default path).
    pub rank: Option<usize>,
    /// Traffic-class label for a per-pair rule (e.g. `"repl"` shapes
    /// only the replication shipper's follower links); `None` shapes
    /// every dialer to the destination.
    pub src: Option<String>,
    pub policy: LinkPolicy,
}

/// Declarative network impairment for a campaign's live plane
/// (DESIGN.md §15): a default policy applied to every link, per-rank
/// overrides, and an optional heal time after which partitions lift.
/// The impaired drivers in `chaos::live` compile this into a
/// [`NetemMap`](crate::comms::NetemMap) fronting the real sockets.
#[derive(Debug, Clone, Default)]
pub struct NetemSpec {
    pub default: Option<LinkPolicy>,
    pub links: Vec<NodeLink>,
    /// Wall-clock seconds after campaign start at which every
    /// partition in the map heals (delay/loss/rate stay in force).
    pub heal_after_s: Option<f64>,
}

impl NetemSpec {
    pub fn validate(&self) -> Result<()> {
        if let Some(p) = &self.default {
            p.validate().map_err(|e| anyhow::anyhow!("netem default: {e}"))?;
        }
        for (i, l) in self.links.iter().enumerate() {
            l.policy
                .validate()
                .map_err(|e| anyhow::anyhow!("netem link {i}: {e}"))?;
        }
        if let Some(h) = self.heal_after_s {
            if h < 0.0 || !h.is_finite() {
                bail!("netem heal_after_s {h} must be finite and >= 0");
            }
        }
        Ok(())
    }
}

fn policy_to_json(p: &LinkPolicy) -> Json {
    let mut o = Json::object();
    if p.delay_ms != 0.0 {
        o.set("delay_ms", p.delay_ms);
    }
    if p.jitter_ms != 0.0 {
        o.set("jitter_ms", p.jitter_ms);
    }
    if p.loss != 0.0 {
        o.set("loss", p.loss);
    }
    if let Some(r) = p.rate_kbps {
        o.set("rate_kbps", r);
    }
    if p.partition != Partition::None {
        o.set("partition", p.partition.name());
    }
    o
}

fn policy_from_json(v: &Json) -> Result<LinkPolicy> {
    let partition = match v.get("partition").as_str() {
        None => Partition::None,
        Some(s) => Partition::parse(s)
            .with_context(|| format!("unknown netem partition {s:?}"))?,
    };
    let p = LinkPolicy {
        delay_ms: v.get("delay_ms").as_f64().unwrap_or(0.0),
        jitter_ms: v.get("jitter_ms").as_f64().unwrap_or(0.0),
        loss: v.get("loss").as_f64().unwrap_or(0.0),
        rate_kbps: v.get("rate_kbps").as_f64(),
        partition,
    };
    p.validate().map_err(|e| anyhow::anyhow!(e))?;
    Ok(p)
}

/// Live-path (in-process controller) run shape.
#[derive(Debug, Clone)]
pub struct LiveShape {
    pub dp: usize,
    pub steps: u64,
}

impl Default for LiveShape {
    fn default() -> Self {
        LiveShape { dp: 2, steps: 12 }
    }
}

/// A complete declarative fault campaign.
#[derive(Debug, Clone)]
pub struct ScenarioSpec {
    pub name: String,
    pub description: String,
    pub mode: RecoveryMode,
    /// Campaign length in simulated seconds (training-time accounting;
    /// recoveries in flight at the horizon still run to completion).
    pub horizon_s: f64,
    pub cluster: ClusterShape,
    pub faults: Vec<FaultSpec>,
    pub assertions: Assertions,
    pub live: LiveShape,
    /// Network impairment applied to the live plane for the campaign;
    /// `None` (the default) leaves every link perfect — and leaves the
    /// rendered JSON (and thus the spec hash) of pre-§15 specs
    /// untouched.
    pub netem: Option<NetemSpec>,
}

impl Default for ScenarioSpec {
    fn default() -> Self {
        ScenarioSpec {
            name: "unnamed".to_string(),
            description: String::new(),
            mode: RecoveryMode::Flash,
            horizon_s: 1800.0,
            cluster: ClusterShape::default(),
            faults: Vec::new(),
            assertions: Assertions::default(),
            live: LiveShape::default(),
            netem: None,
        }
    }
}

impl ScenarioSpec {
    pub fn validate(&self) -> Result<()> {
        if self.cluster.devices == 0 || self.cluster.devices_per_node == 0 {
            bail!("cluster must have devices and devices_per_node >= 1");
        }
        if self.horizon_s <= 0.0 {
            bail!("horizon_s must be positive");
        }
        let active = self.cluster.active_nodes();
        for (i, f) in self.faults.iter().enumerate() {
            if f.at_s < 0.0 || f.at_s > self.horizon_s {
                bail!("fault {i}: at_s {} outside [0, horizon]", f.at_s);
            }
            if let Some(n) = f.node {
                if n >= active {
                    bail!("fault {i}: node {n} >= active nodes {active}");
                }
            }
            if f.nodes == 0 {
                bail!("fault {i}: nodes must be >= 1");
            }
            match f.family {
                FaultFamily::Straggler if f.slowdown < 1.0 => {
                    bail!("fault {i}: straggler slowdown must be >= 1.0")
                }
                FaultFamily::Flap if f.times == 0 => {
                    bail!("fault {i}: flap times must be >= 1")
                }
                FaultFamily::Partition if f.nodes > active => {
                    bail!("fault {i}: partition of {} > {active} nodes", f.nodes)
                }
                _ => {}
            }
        }
        if let Some(n) = &self.netem {
            n.validate()?;
        }
        Ok(())
    }

    /// FNV-1a over the canonical rendering: the spec's identity in
    /// journals (`(spec_hash, seed)` is the determinism key).
    pub fn hash(&self) -> u64 {
        crate::util::fnv1a(self.to_json().render().as_bytes())
    }

    pub fn to_json(&self) -> Json {
        let mut cl = Json::object();
        cl.set("devices", self.cluster.devices)
            .set("devices_per_node", self.cluster.devices_per_node)
            .set("spare_nodes", self.cluster.spare_nodes)
            .set("model_params", self.cluster.model_params)
            .set("tcp_parallelism", self.cluster.tcp_parallelism)
            .set("heartbeat_interval_s", self.cluster.heartbeat_interval_s)
            .set("miss_threshold", self.cluster.miss_threshold as u64)
            .set("collective_timeout_s", self.cluster.collective_timeout_s)
            .set("ckpt_interval_steps", self.cluster.ckpt_interval_steps)
            .set("straggler_evict_after_s", self.cluster.straggler_evict_after_s)
            .set(
                "straggler_evict_threshold",
                self.cluster.straggler_evict_threshold,
            );
        if let Some(r) = self.cluster.rejoin_s {
            cl.set("rejoin_s", r);
        }

        let faults: Vec<Json> = self
            .faults
            .iter()
            .map(|f| {
                let mut o = Json::object();
                o.set("kind", f.family.name()).set("at_s", f.at_s);
                if let Some(n) = f.node {
                    o.set("node", n);
                }
                match f.family {
                    FaultFamily::Cascade | FaultFamily::Partition => {
                        o.set("nodes", f.nodes);
                        if f.family == FaultFamily::Cascade {
                            o.set("spacing_s", f.spacing_s);
                        }
                    }
                    FaultFamily::Flap => {
                        o.set("times", f.times).set("period_s", f.period_s);
                        o.set("period_steps", f.period_steps);
                    }
                    FaultFamily::Straggler => {
                        o.set("slowdown", f.slowdown)
                            .set("duration_s", f.duration_s);
                    }
                    _ => {}
                }
                if let Some(k) = f.failure {
                    o.set("failure", k.name());
                }
                if let Some(r) = f.rank {
                    o.set("rank", r);
                }
                if let Some(s) = f.at_step {
                    o.set("at_step", s);
                }
                if f.phase != "fwdbwd" {
                    o.set("phase", f.phase.as_str());
                }
                o
            })
            .collect();

        let a = &self.assertions;
        let mut aj = Json::object();
        aj.set("require_all_recovered", a.require_all_recovered)
            .set("expect_spare_exhaustion", a.expect_spare_exhaustion);
        if let Some(v) = a.max_single_recovery_s {
            aj.set("max_single_recovery_s", v);
        }
        if let Some(v) = a.max_total_downtime_s {
            aj.set("max_total_downtime_s", v);
        }
        if let Some(v) = a.max_lost_steps {
            aj.set("max_lost_steps", v);
        }
        if let Some(v) = a.min_recoveries {
            aj.set("min_recoveries", v);
        }
        if let Some(v) = a.min_merged_recoveries {
            aj.set("min_merged_recoveries", v);
        }
        if let Some(v) = a.min_steps_completed {
            aj.set("min_steps_completed", v);
        }
        if let Some(v) = a.min_final_running_nodes {
            aj.set("min_final_running_nodes", v);
        }
        if let Some(v) = a.min_stragglers_evicted {
            aj.set("min_stragglers_evicted", v);
        }

        let mut lv = Json::object();
        lv.set("dp", self.live.dp).set("steps", self.live.steps);

        let mut o = Json::object();
        o.set("name", self.name.as_str())
            .set("description", self.description.as_str())
            .set("mode", self.mode.name())
            .set("horizon_s", self.horizon_s)
            .set("cluster", cl)
            .set("faults", Json::Array(faults))
            .set("assertions", aj)
            .set("live", lv);
        // Emitted only when present: pre-§15 specs keep their hash.
        if let Some(n) = &self.netem {
            let mut nj = Json::object();
            if let Some(p) = &n.default {
                nj.set("default", policy_to_json(p));
            }
            if !n.links.is_empty() {
                let links: Vec<Json> = n
                    .links
                    .iter()
                    .map(|l| {
                        let mut o = policy_to_json(&l.policy);
                        if let Some(r) = l.rank {
                            o.set("rank", r);
                        }
                        // Emitted only when present: pre-§16 specs
                        // keep their hash.
                        if let Some(s) = &l.src {
                            o.set("src", s.as_str());
                        }
                        o
                    })
                    .collect();
                nj.set("links", Json::Array(links));
            }
            if let Some(h) = n.heal_after_s {
                nj.set("heal_after_s", h);
            }
            o.set("netem", nj);
        }
        o
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let d = ScenarioSpec::default();
        let cl = v.get("cluster");
        let dc = ClusterShape::default();
        let cluster = ClusterShape {
            devices: cl.get("devices").as_usize().unwrap_or(dc.devices),
            devices_per_node: cl
                .get("devices_per_node")
                .as_usize()
                .unwrap_or(dc.devices_per_node),
            spare_nodes: cl.get("spare_nodes").as_usize().unwrap_or(dc.spare_nodes),
            model_params: cl.get("model_params").as_f64().unwrap_or(dc.model_params),
            tcp_parallelism: cl
                .get("tcp_parallelism")
                .as_usize()
                .unwrap_or(dc.tcp_parallelism),
            heartbeat_interval_s: cl
                .get("heartbeat_interval_s")
                .as_f64()
                .unwrap_or(dc.heartbeat_interval_s),
            miss_threshold: cl
                .get("miss_threshold")
                .as_usize()
                .unwrap_or(dc.miss_threshold as usize) as u32,
            collective_timeout_s: cl
                .get("collective_timeout_s")
                .as_f64()
                .unwrap_or(dc.collective_timeout_s),
            rejoin_s: cl.get("rejoin_s").as_f64(),
            ckpt_interval_steps: cl
                .get("ckpt_interval_steps")
                .as_i64()
                .unwrap_or(dc.ckpt_interval_steps as i64) as u64,
            straggler_evict_after_s: cl
                .get("straggler_evict_after_s")
                .as_f64()
                .unwrap_or(dc.straggler_evict_after_s),
            straggler_evict_threshold: cl
                .get("straggler_evict_threshold")
                .as_f64()
                .unwrap_or(dc.straggler_evict_threshold),
        };

        let mut faults = Vec::new();
        if let Some(items) = v.get("faults").as_array() {
            for (i, fj) in items.iter().enumerate() {
                let df = FaultSpec::default();
                let family = FaultFamily::parse(
                    fj.get("kind").as_str().with_context(|| {
                        format!("fault {i}: missing \"kind\"")
                    })?,
                )?;
                let failure = match fj.get("failure").as_str() {
                    None => None,
                    Some(name) => Some(FailureKind::from_name(name).with_context(
                        || format!("fault {i}: unknown failure {name:?}"),
                    )?),
                };
                faults.push(FaultSpec {
                    family,
                    at_s: fj.get("at_s").as_f64().unwrap_or(df.at_s),
                    node: fj.get("node").as_usize(),
                    nodes: fj.get("nodes").as_usize().unwrap_or(df.nodes),
                    spacing_s: fj.get("spacing_s").as_f64().unwrap_or(df.spacing_s),
                    times: fj.get("times").as_usize().unwrap_or(df.times),
                    period_s: fj.get("period_s").as_f64().unwrap_or(df.period_s),
                    slowdown: fj.get("slowdown").as_f64().unwrap_or(df.slowdown),
                    duration_s: fj.get("duration_s").as_f64().unwrap_or(df.duration_s),
                    failure,
                    rank: fj.get("rank").as_usize(),
                    at_step: fj.get("at_step").as_i64().map(|s| s.max(0) as u64),
                    period_steps: fj
                        .get("period_steps")
                        .as_i64()
                        .unwrap_or(df.period_steps as i64)
                        .max(1) as u64,
                    phase: fj
                        .get("phase")
                        .as_str()
                        .unwrap_or(&df.phase)
                        .to_string(),
                });
            }
        }

        let aj = v.get("assertions");
        let da = Assertions::default();
        let assertions = Assertions {
            max_single_recovery_s: aj.get("max_single_recovery_s").as_f64(),
            max_total_downtime_s: aj.get("max_total_downtime_s").as_f64(),
            max_lost_steps: aj.get("max_lost_steps").as_i64().map(|v| v.max(0) as u64),
            require_all_recovered: aj
                .get("require_all_recovered")
                .as_bool()
                .unwrap_or(da.require_all_recovered),
            min_recoveries: aj.get("min_recoveries").as_usize(),
            min_merged_recoveries: aj.get("min_merged_recoveries").as_usize(),
            expect_spare_exhaustion: aj
                .get("expect_spare_exhaustion")
                .as_bool()
                .unwrap_or(da.expect_spare_exhaustion),
            min_steps_completed: aj
                .get("min_steps_completed")
                .as_i64()
                .map(|v| v.max(0) as u64),
            min_final_running_nodes: aj.get("min_final_running_nodes").as_usize(),
            min_stragglers_evicted: aj.get("min_stragglers_evicted").as_usize(),
        };

        let nj = v.get("netem");
        let netem = if nj.is_null() {
            None
        } else {
            let default = if nj.get("default").is_null() {
                None
            } else {
                Some(policy_from_json(nj.get("default")).context("netem default")?)
            };
            let mut links = Vec::new();
            if let Some(items) = nj.get("links").as_array() {
                for (i, lj) in items.iter().enumerate() {
                    links.push(NodeLink {
                        rank: lj.get("rank").as_usize(),
                        src: lj.get("src").as_str().map(String::from),
                        policy: policy_from_json(lj)
                            .with_context(|| format!("netem link {i}"))?,
                    });
                }
            }
            Some(NetemSpec {
                default,
                links,
                heal_after_s: nj.get("heal_after_s").as_f64(),
            })
        };

        let lv = v.get("live");
        let dl = LiveShape::default();
        let spec = ScenarioSpec {
            name: v.get("name").as_str().unwrap_or(&d.name).to_string(),
            description: v
                .get("description")
                .as_str()
                .unwrap_or("")
                .to_string(),
            mode: RecoveryMode::parse(v.get("mode").as_str().unwrap_or("flash"))?,
            horizon_s: v.get("horizon_s").as_f64().unwrap_or(d.horizon_s),
            cluster,
            faults,
            assertions,
            live: LiveShape {
                dp: lv.get("dp").as_usize().unwrap_or(dl.dp),
                steps: lv.get("steps").as_i64().unwrap_or(dl.steps as i64) as u64,
            },
            netem,
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {:?}", path.as_ref()))?;
        let v = Json::parse(&text).context("parsing scenario spec")?;
        Self::from_json(&v)
    }

    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        std::fs::write(path, self.to_json().render_pretty())?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::chaos::library;

    #[test]
    fn library_specs_roundtrip_and_hash_stably() {
        for spec in library::all(256) {
            spec.validate().unwrap();
            let back = ScenarioSpec::from_json(&spec.to_json()).unwrap();
            assert_eq!(back.name, spec.name);
            assert_eq!(back.faults.len(), spec.faults.len());
            assert_eq!(back.hash(), spec.hash(), "{}: hash unstable", spec.name);
        }
    }

    #[test]
    fn rejects_bad_specs() {
        let mut s = ScenarioSpec::default();
        s.horizon_s = -1.0;
        assert!(s.validate().is_err());

        let mut s = ScenarioSpec::default();
        s.faults.push(FaultSpec { at_s: 1e9, ..Default::default() });
        assert!(s.validate().is_err());

        let mut s = ScenarioSpec::default();
        s.faults.push(FaultSpec { node: Some(9999), ..Default::default() });
        assert!(s.validate().is_err());

        assert!(FaultFamily::parse("meteor_strike").is_err());
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::util::temp_dir("chaos-spec").unwrap();
        let path = dir.join("spec.json");
        let spec = library::by_name("rolling_cascade", 128).unwrap();
        spec.save(&path).unwrap();
        let back = ScenarioSpec::load(&path).unwrap();
        assert_eq!(back.hash(), spec.hash());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn netem_section_roundtrips_and_leaves_plain_specs_untouched() {
        // Pre-§15 specs must render (and hash) exactly as before.
        let plain = library::by_name("single_fault", 256).unwrap();
        assert!(plain.netem.is_none());
        assert!(!plain.to_json().render().contains("netem"));

        let spec = library::by_name("partition_heal_rendezvous", 256).unwrap();
        let back = ScenarioSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back.hash(), spec.hash());
        let n = back.netem.expect("netem section survives the roundtrip");
        assert_eq!(n.default.unwrap().delay_ms, 5.0);
        assert_eq!(n.links.len(), 1);
        assert_eq!(n.links[0].rank, Some(2));
        assert_eq!(n.links[0].policy.partition, Partition::Both);
        assert_eq!(n.heal_after_s, Some(0.4));

        let lossy = library::by_name("detection_under_loss", 256).unwrap();
        let back = ScenarioSpec::from_json(&lossy.to_json()).unwrap();
        assert_eq!(back.netem.unwrap().default.unwrap().loss, 0.30);
    }

    #[test]
    fn netem_rejects_nonsense() {
        let mut s = ScenarioSpec::default();
        s.netem = Some(NetemSpec {
            default: Some(LinkPolicy::lossy(1.5)),
            links: Vec::new(),
            heal_after_s: None,
        });
        assert!(s.validate().is_err());

        let mut s = ScenarioSpec::default();
        s.netem = Some(NetemSpec {
            default: None,
            links: Vec::new(),
            heal_after_s: Some(-1.0),
        });
        assert!(s.validate().is_err());

        let v = Json::parse(r#"{"netem":{"default":{"partition":"sideways"}}}"#)
            .unwrap();
        assert!(ScenarioSpec::from_json(&v).is_err());
    }

    #[test]
    fn unknown_failure_name_errors() {
        let v = Json::parse(
            r#"{"faults":[{"kind":"crash","at_s":1,"failure":"gamma_ray"}]}"#,
        )
        .unwrap();
        assert!(ScenarioSpec::from_json(&v).is_err());
    }
}
