//! Built-in chaos scenario library.
//!
//! Fifteen parameterized campaigns, from the paper's single-failure
//! baseline to compound patterns production fleets actually see
//! (ByteDance's robust-training report, Unicron): concurrent faults,
//! rolling cascades, flapping hosts, failures striking mid-recovery,
//! spare-pool exhaustion, straggler degradation, failures landing
//! mid-*restore* (state streams aborted and replanned), silent
//! hangs (alive worker, frozen step tag), coordination-plane
//! failover — the store primary dying mid-rendezvous and the
//! controller dying mid-restore (DESIGN.md §13) — impaired-plane
//! campaigns where the same faults land over degraded links: detection
//! under 30% loss, restore across a WAN, rendezvous across a partition
//! heal (DESIGN.md §15) — and the redundancy-tier worst case: an
//! entire ZeRO replica group wiped out mid-step, the shard rebuilt
//! bit-exact from erasure stripes with zero checkpoint reads
//! (DESIGN.md §16). Each spec carries
//! assertions calibrated to the paper-fit latency model — recovery-time
//! bounds are intentionally scale-independent (the paper's headline
//! claim), so the same spec passes from 64 to 18k devices.
//!
//! `benches/chaos_campaigns.rs` sweeps the library across scales;
//! `scenario run --spec <name>` runs one by name.

use super::spec::{
    Assertions, ClusterShape, FaultFamily, FaultSpec, NetemSpec, NodeLink, ScenarioSpec,
};
use crate::cluster::failure::FailureKind;
use crate::comms::netem::{LinkPolicy, Partition};
use crate::config::RecoveryMode;

/// Names of all built-in scenarios, in presentation order.
pub const NAMES: [&str; 15] = [
    "single_fault",
    "double_fault",
    "rolling_cascade",
    "flaky_node",
    "failure_during_recovery",
    "spare_exhaustion",
    "straggler_degrade",
    "restore_under_churn",
    "silent_hang",
    "store_crash_mid_rendezvous",
    "controller_crash_mid_restore",
    "detection_under_loss",
    "restore_over_wan",
    "partition_heal_rendezvous",
    "replica_group_wipeout",
];

fn base(name: &str, description: &str, devices: usize) -> ScenarioSpec {
    ScenarioSpec {
        name: name.to_string(),
        description: description.to_string(),
        mode: RecoveryMode::Flash,
        horizon_s: 1800.0,
        cluster: ClusterShape { devices, ..Default::default() },
        faults: Vec::new(),
        assertions: Assertions::default(),
        live: Default::default(),
        netem: None,
    }
}

/// Paper baseline: one failure, sampled from the Fig. 9 mix, mid-run.
pub fn single_fault(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "single_fault",
        "Paper baseline: one sampled failure at t=120s, checkpoint-free recovery",
        devices,
    );
    s.faults.push(FaultSpec { at_s: 120.0, ..Default::default() });
    s.faults[0].rank = Some(1);
    s.faults[0].at_step = Some(4);
    s.assertions = Assertions {
        max_single_recovery_s: Some(250.0),
        max_total_downtime_s: Some(300.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        min_steps_completed: Some(60),
        ..Default::default()
    };
    s
}

/// Two concurrent failures on distinct nodes — one merged recovery.
pub fn double_fault(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "double_fault",
        "Two simultaneous crashes on distinct nodes absorbed by one recovery",
        devices,
    );
    s.cluster.spare_nodes = 2;
    let mut f1 = FaultSpec { at_s: 150.0, ..Default::default() };
    f1.rank = Some(1);
    f1.at_step = Some(4);
    let mut f2 = FaultSpec { at_s: 150.0, ..Default::default() };
    f2.rank = Some(2);
    f2.at_step = Some(4);
    s.faults = vec![f1, f2];
    s.live.dp = 4;
    s.assertions = Assertions {
        max_single_recovery_s: Some(300.0),
        max_total_downtime_s: Some(350.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        min_merged_recoveries: Some(1),
        ..Default::default()
    };
    s
}

/// Rolling cascade: four crashes 30s apart, each landing inside the
/// previous recovery window.
pub fn rolling_cascade(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "rolling_cascade",
        "Four-node rolling cascade at 30s spacing — recovery keeps absorbing new victims",
        devices,
    );
    s.cluster.spare_nodes = 4;
    s.faults.push(FaultSpec {
        family: FaultFamily::Cascade,
        at_s: 120.0,
        nodes: 4,
        spacing_s: 30.0,
        ..Default::default()
    });
    s.assertions = Assertions {
        max_single_recovery_s: Some(450.0),
        max_total_downtime_s: Some(600.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        min_merged_recoveries: Some(1),
        ..Default::default()
    };
    s
}

/// One flapping host: fails, is substituted, repairs, rejoins the
/// spare pool, and fails again — three times.
pub fn flaky_node(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "flaky_node",
        "One device block fails three times; repaired hosts rejoin the spare pool",
        devices,
    );
    s.cluster.spare_nodes = 1;
    s.cluster.rejoin_s = Some(150.0);
    s.horizon_s = 1500.0;
    let mut f = FaultSpec {
        family: FaultFamily::Flap,
        at_s: 200.0,
        times: 3,
        period_s: 400.0,
        ..Default::default()
    };
    f.rank = Some(1);
    f.at_step = Some(3);
    f.period_steps = 4;
    s.live.steps = 16;
    s.faults.push(f);
    s.assertions = Assertions {
        max_single_recovery_s: Some(250.0),
        max_total_downtime_s: Some(800.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(3),
        min_steps_completed: Some(40),
        ..Default::default()
    };
    s
}

/// A second failure strikes while the first recovery is mid-restart.
pub fn failure_during_recovery(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "failure_during_recovery",
        "Second crash lands inside the first restart window; recovery merges it",
        devices,
    );
    s.cluster.spare_nodes = 2;
    s.faults.push(FaultSpec {
        at_s: 100.0,
        failure: Some(FailureKind::Network),
        ..Default::default()
    });
    s.faults.push(FaultSpec {
        at_s: 130.0,
        failure: Some(FailureKind::Segfault),
        ..Default::default()
    });
    s.assertions = Assertions {
        max_single_recovery_s: Some(350.0),
        max_total_downtime_s: Some(400.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        min_merged_recoveries: Some(1),
        ..Default::default()
    };
    s
}

/// A second failure strikes while the first failure's *state restore*
/// is mid-transfer: the epoch bump must abort every in-flight shard
/// stream retryably and the replanned restore (both victims folded
/// into one episode) must still converge. On the simulator path this
/// behaves like `failure_during_recovery`; the live hints drive
/// `chaos::live::drive_restores_under_churn` over real sockets.
pub fn restore_under_churn(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "restore_under_churn",
        "Second crash lands mid-restore; epoch bump aborts in-flight state streams, replanned restore converges",
        devices,
    );
    s.cluster.spare_nodes = 2;
    let mut f1 = FaultSpec {
        at_s: 100.0,
        failure: Some(FailureKind::Network),
        ..Default::default()
    };
    f1.rank = Some(1);
    f1.at_step = Some(4);
    let mut f2 = FaultSpec {
        at_s: 130.0,
        failure: Some(FailureKind::Segfault),
        ..Default::default()
    };
    f2.rank = Some(2);
    f2.at_step = Some(6);
    s.faults = vec![f1, f2];
    s.live.dp = 4;
    s.assertions = Assertions {
        max_single_recovery_s: Some(350.0),
        max_total_downtime_s: Some(400.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        min_merged_recoveries: Some(1),
        ..Default::default()
    };
    s
}

/// An *alive* worker silently stops making progress — stuck in a
/// collective, wedged driver, hard straggler — while its liveness
/// flag stays green. On the simulator path this is a severe straggler
/// evicted after the patience window; the live hints drive
/// `chaos::live::drive_live_detection`, where the wire monitor must
/// catch the frozen step tag via the stall-vs-median rule and chain
/// detection → group rebuild → shard restore over real sockets
/// (DESIGN.md §10).
pub fn silent_hang(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "silent_hang",
        "Alive-but-stuck worker: frozen step tag caught by DP-median stall detection, evicted, recovered end to end",
        devices,
    );
    s.cluster.spare_nodes = 1;
    let mut f = FaultSpec {
        family: FaultFamily::Straggler,
        at_s: 150.0,
        slowdown: 4.0,
        duration_s: 600.0,
        ..Default::default()
    };
    f.rank = Some(1);
    f.at_step = Some(4);
    s.faults.push(f);
    s.live.dp = 4;
    s.assertions = Assertions {
        max_single_recovery_s: Some(250.0),
        max_total_downtime_s: Some(300.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        min_stragglers_evicted: Some(1),
        ..Default::default()
    };
    s
}

/// More simultaneous victims than spares: the pool empties, one node
/// stays failed, and the job degrades gracefully instead of wedging.
pub fn spare_exhaustion(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "spare_exhaustion",
        "Simultaneous crashes exceed the spare pool; job degrades without deadlock",
        devices,
    );
    s.cluster.spare_nodes = 1;
    s.faults.push(FaultSpec {
        family: FaultFamily::SpareExhaustion,
        at_s: 120.0,
        ..Default::default()
    });
    let active = s.cluster.active_nodes();
    s.assertions = Assertions {
        max_single_recovery_s: Some(300.0),
        require_all_recovered: false,
        expect_spare_exhaustion: true,
        min_recoveries: Some(1),
        min_final_running_nodes: Some(active.saturating_sub(1)),
        min_steps_completed: Some(1),
        ..Default::default()
    };
    s
}

/// A straggler slows the synchronous job 3x; flash evicts it after the
/// patience window and substitutes a healthy node.
pub fn straggler_degrade(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "straggler_degrade",
        "3x straggler paces the whole DP group; degrade-aware eviction recovers throughput",
        devices,
    );
    s.faults.push(FaultSpec {
        family: FaultFamily::Straggler,
        at_s: 150.0,
        slowdown: 3.0,
        duration_s: 600.0,
        ..Default::default()
    });
    s.assertions = Assertions {
        max_single_recovery_s: Some(250.0),
        max_total_downtime_s: Some(300.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        min_stragglers_evicted: Some(1),
        ..Default::default()
    };
    s
}

/// The coordination plane's own primary dies mid-rendezvous: the
/// store crash lands while rendezvous waits are parked on it. On the
/// simulator path this behaves like `single_fault` (the latency model
/// folds coordination-plane failover into the restart stage); the
/// live hints drive `chaos::live::drive_store_crash_mid_rendezvous`,
/// where the parked wait must fail over to the promoted replica and
/// wake exactly once, with the survivor re-key budget intact
/// (DESIGN.md §13).
pub fn store_crash_mid_rendezvous(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "store_crash_mid_rendezvous",
        "Store primary killed while rendezvous waits are parked; promoted replica finishes the episode",
        devices,
    );
    s.faults.push(FaultSpec { at_s: 120.0, ..Default::default() });
    s.faults[0].rank = Some(1);
    s.faults[0].at_step = Some(4);
    s.live.dp = 4;
    s.assertions = Assertions {
        max_single_recovery_s: Some(250.0),
        max_total_downtime_s: Some(300.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        min_steps_completed: Some(60),
        ..Default::default()
    };
    s
}

/// The controller crashes between group rebuild and state restore —
/// together with its co-located store primary. On the simulator path
/// this behaves like `single_fault` with a slightly later strike; the
/// live hints drive `chaos::live::drive_controller_crash_mid_restore`,
/// where a standby controller must adopt the lease table and the
/// in-flight episode checkpoint from the promoted replica and finish
/// the restore bit-exactly (DESIGN.md §13).
pub fn controller_crash_mid_restore(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "controller_crash_mid_restore",
        "Controller and store primary crash after rebuild; standby adopts the episode checkpoint and finishes the restore",
        devices,
    );
    s.cluster.spare_nodes = 1;
    s.faults.push(FaultSpec { at_s: 130.0, ..Default::default() });
    s.faults[0].rank = Some(1);
    s.faults[0].at_step = Some(4);
    s.live.dp = 4;
    s.assertions = Assertions {
        max_single_recovery_s: Some(250.0),
        max_total_downtime_s: Some(300.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        min_steps_completed: Some(60),
        ..Default::default()
    };
    s
}

/// Failure detection over a badly lossy plane: every heartbeat and
/// store op crosses a link dropping 30% of its MTU chunks. On the
/// simulator path this behaves like `single_fault`; the live hints
/// drive `chaos::live::drive_netem_detection`, where the lease monitor
/// must still catch the crash — with deadlines widened by the §15
/// `Timeouts` scaling rather than hand-tuned — and never falsely evict
/// a survivor whose beats are merely delayed by retransmission.
pub fn detection_under_loss(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "detection_under_loss",
        "Rank crash detected through a 30%-loss plane; retransmit-delayed beats never falsely evict survivors",
        devices,
    );
    s.cluster.spare_nodes = 1;
    s.faults.push(FaultSpec { at_s: 120.0, ..Default::default() });
    s.faults[0].rank = Some(1);
    s.faults[0].at_step = Some(4);
    s.live.dp = 4;
    s.netem = Some(NetemSpec {
        default: Some(LinkPolicy::lossy(0.30)),
        links: Vec::new(),
        heal_after_s: None,
    });
    s.assertions = Assertions {
        max_single_recovery_s: Some(300.0),
        max_total_downtime_s: Some(350.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        ..Default::default()
    };
    s
}

/// Shard restore over a cross-region WAN: the replacement pulls its
/// state across a 50 ms-RTT link with jitter and light loss. On the
/// simulator path this behaves like `single_fault`; the live hints
/// drive `chaos::live::drive_netem_restore`, where the state stream's
/// io-stall watchdog (scaled from `Timeouts`) must ride out the
/// latency and the fetch must land bit-exact.
pub fn restore_over_wan(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "restore_over_wan",
        "Replacement restores its shard over a 50ms-RTT jittery WAN link, bit-exact, within widened deadlines",
        devices,
    );
    s.cluster.spare_nodes = 1;
    s.faults.push(FaultSpec { at_s: 120.0, ..Default::default() });
    s.faults[0].rank = Some(1);
    s.faults[0].at_step = Some(4);
    s.live.dp = 2;
    s.netem = Some(NetemSpec {
        // 25ms each way = 50ms RTT, ±5ms jitter, 0.5% loss.
        default: Some(LinkPolicy::wan(25.0, 5.0, 0.005)),
        links: Vec::new(),
        heal_after_s: None,
    });
    s.assertions = Assertions {
        max_single_recovery_s: Some(350.0),
        max_total_downtime_s: Some(400.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        ..Default::default()
    };
    s
}

/// Rendezvous across a partition heal: one survivor's link to the
/// store is fully severed when the episode starts and only heals
/// mid-rendezvous; the healed link stays slow. On the simulator path
/// this behaves like `single_fault`; the live hints drive
/// `chaos::live::drive_netem_partition_heal`, where the supervised
/// barrier (widened via `Timeouts::scaled_for_rtt`) must hold open
/// long enough for the healed rank's jittered reconnect to land — one
/// release, no abort.
pub fn partition_heal_rendezvous(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "partition_heal_rendezvous",
        "Severed rank heals mid-rendezvous onto a slow link; widened barrier releases once, no false abort",
        devices,
    );
    s.cluster.spare_nodes = 1;
    s.faults.push(FaultSpec { at_s: 120.0, ..Default::default() });
    s.faults[0].rank = Some(1);
    s.faults[0].at_step = Some(4);
    s.live.dp = 4;
    s.netem = Some(NetemSpec {
        default: Some(LinkPolicy::delay(5.0)),
        links: vec![NodeLink {
            rank: Some(2),
            src: None,
            policy: LinkPolicy {
                delay_ms: 10.0,
                partition: Partition::Both,
                ..Default::default()
            },
        }],
        heal_after_s: Some(0.4),
    });
    s.assertions = Assertions {
        max_single_recovery_s: Some(350.0),
        max_total_downtime_s: Some(400.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        ..Default::default()
    };
    s
}

/// The redundancy tier's worst case: *both* holders of one ZeRO shard
/// (dp=4, zero=2: ranks 1 and 3) die in the same step, so no live
/// replica can source the restore. On the simulator path this behaves
/// like `double_fault`; the live hints drive
/// `chaos::live::drive_replica_group_wipeout`, where the restore
/// planner must report the shard unsourced, the stripe directory must
/// cover it (any k of k+m erasure stripes shipped during idle step
/// time), and the reconstruction must land bit-exact with **zero**
/// checkpoint file reads (DESIGN.md §16).
pub fn replica_group_wipeout(devices: usize) -> ScenarioSpec {
    let mut s = base(
        "replica_group_wipeout",
        "Entire ZeRO replica group killed mid-step; shard rebuilt bit-exact from erasure stripes, zero checkpoint reads",
        devices,
    );
    s.cluster.spare_nodes = 2;
    let mut f1 = FaultSpec { at_s: 140.0, ..Default::default() };
    f1.rank = Some(1);
    f1.at_step = Some(6);
    let mut f2 = FaultSpec { at_s: 140.0, ..Default::default() };
    f2.rank = Some(3);
    f2.at_step = Some(6);
    s.faults = vec![f1, f2];
    s.live.dp = 4;
    s.assertions = Assertions {
        max_single_recovery_s: Some(350.0),
        max_total_downtime_s: Some(400.0),
        max_lost_steps: Some(0),
        min_recoveries: Some(1),
        min_merged_recoveries: Some(1),
        ..Default::default()
    };
    s
}

/// All built-in scenarios at the given device count.
pub fn all(devices: usize) -> Vec<ScenarioSpec> {
    NAMES
        .iter()
        .map(|n| by_name(n, devices).expect("library name"))
        .collect()
}

/// Look up one built-in scenario by name.
pub fn by_name(name: &str, devices: usize) -> Option<ScenarioSpec> {
    Some(match name {
        "single_fault" => single_fault(devices),
        "double_fault" => double_fault(devices),
        "rolling_cascade" => rolling_cascade(devices),
        "flaky_node" => flaky_node(devices),
        "failure_during_recovery" => failure_during_recovery(devices),
        "spare_exhaustion" => spare_exhaustion(devices),
        "straggler_degrade" => straggler_degrade(devices),
        "restore_under_churn" => restore_under_churn(devices),
        "silent_hang" => silent_hang(devices),
        "store_crash_mid_rendezvous" => store_crash_mid_rendezvous(devices),
        "controller_crash_mid_restore" => controller_crash_mid_restore(devices),
        "detection_under_loss" => detection_under_loss(devices),
        "restore_over_wan" => restore_over_wan(devices),
        "partition_heal_rendezvous" => partition_heal_rendezvous(devices),
        "replica_group_wipeout" => replica_group_wipeout(devices),
        _ => return None,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_and_builders_agree() {
        for n in NAMES {
            let s = by_name(n, 256).unwrap();
            assert_eq!(s.name, n);
            s.validate().unwrap();
            assert!(!s.description.is_empty());
        }
        assert!(by_name("nope", 256).is_none());
        assert_eq!(all(256).len(), NAMES.len());
    }

    #[test]
    fn library_scales_without_revalidation_errors() {
        for devices in [64, 1024, 18_000] {
            for s in all(devices) {
                s.validate().unwrap();
            }
        }
    }
}
