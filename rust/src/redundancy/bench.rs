//! `bench redundancy` — the redundancy tier's cost/benefit table.
//!
//! Steady-state columns bound what the tier costs per training step
//! (`ship p50 ms` with every stripe dirty — the worst case — and
//! `reship p50 ms` when nothing changed — the delta fast path), and
//! the recovery columns compare what it buys: stripe reconstruction
//! (`rebuild ms`, the whole-replica-group-death path) against a
//! replica-sourced stream (`replica ms`, the path that needs a live
//! replica) and the file-checkpoint fallback (`ckpt ms`, the path
//! FlashRecovery exists to avoid). CI gates column 0 against
//! `ci/BENCH_redundancy.baseline.json`.

use super::*;
use crate::comms::state_stream::{fetch_snapshot, serve_snapshot};
use crate::comms::tcp_store::TcpStoreServer;
use crate::coordinator::restore::synthetic_snapshot;
use crate::metrics::bench::BenchReport;
use crate::metrics::Histogram;
use std::net::TcpListener;

/// Sweep dimensions for `bench redundancy`.
#[derive(Debug, Clone)]
pub struct RedundancySweepConfig {
    /// Model sizes as f32 elements per shard snapshot.
    pub sizes: Vec<usize>,
    /// Measured rounds per cell (one extra warmup is discarded).
    pub samples: u32,
    pub k: usize,
    pub m: usize,
    pub chunk_bytes: usize,
}

impl Default for RedundancySweepConfig {
    fn default() -> Self {
        RedundancySweepConfig {
            sizes: vec![262_144, 1_048_576],
            samples: 5,
            k: 2,
            m: 1,
            chunk_bytes: crate::comms::state_stream::DEFAULT_CHUNK_BYTES,
        }
    }
}

/// Run the sweep. Column 0 (`ship p50 ms`) is what CI's bench gate
/// compares against the committed baseline.
pub fn redundancy_sweep(cfg: &RedundancySweepConfig) -> Result<BenchReport> {
    let erasure = ErasureConfig::new(cfg.k, cfg.m)?;
    let mut report = BenchReport::new(
        "redundancy",
        &[
            "ship p50 ms",
            "reship p50 ms",
            "rebuild ms",
            "replica ms",
            "ckpt ms",
            "MB shipped",
        ],
    );
    report.note(format!(
        "k={} m={} chunk={} KiB; ship = every stripe dirty, reship = delta \
         fast path; rebuild = whole-replica-group death",
        cfg.k,
        cfg.m,
        cfg.chunk_bytes / 1024
    ));
    let shard = ShardId { pp: 0, tp: 0, zero: 0 };
    let tmp = std::env::temp_dir().join(format!(
        "flashrecovery-bench-redund-{}",
        std::process::id()
    ));
    std::fs::create_dir_all(&tmp)?;
    for &elems in &cfg.sizes {
        let server = TcpStoreServer::start()?;
        let fence = EpochFence::new(1);
        let mut session = StoreSession::try_connect(&server.endpoints())?;
        let rcfg = RedundancyConfig {
            erasure,
            chunk_bytes: cfg.chunk_bytes,
            throttle: None,
        };
        let mut depots = Vec::new();
        let mut holders = Vec::new();
        for i in 0..rcfg.total() {
            let d = StripeDepot::start(fence.clone(), cfg.chunk_bytes)?;
            d.advertise(&mut session, 100 + i)?;
            holders.push((100 + i, d.addr()));
            depots.push(d);
        }
        let mut shipper = StripeShipper::new(
            &server.endpoints(),
            rcfg,
            shard,
            holders,
            fence.clone(),
        )?;

        // steady state, every stripe dirty: each step perturbs the
        // whole snapshot, the worst case for the tier
        let mut ship_h = Histogram::new();
        let mut shipped_mb = 0.0;
        let mut last_step = 0;
        for s in 0..=u64::from(cfg.samples) {
            let snap = synthetic_snapshot(s, elems);
            let stats = shipper
                .ship(&snap, 1)
                .map_err(|e| anyhow!("bench ship: {e}"))?;
            if s > 0 {
                ship_h.record(stats.wall_s);
                shipped_mb += stats.bytes as f64 / 1e6;
            }
            last_step = s;
        }

        // delta fast path: nothing changed, every stripe refreshes
        let mut reship_h = Histogram::new();
        let snap = synthetic_snapshot(last_step, elems);
        for s in 0..=cfg.samples {
            let stats = shipper
                .ship(&snap, 1)
                .map_err(|e| anyhow!("bench reship: {e}"))?;
            if s > 0 {
                reship_h.record(stats.wall_s);
            }
        }

        // recovery: the whole replica group is gone, rebuild from
        // stripes advertised one epoch back
        session.advance_epoch(2)?;
        fence.advance(2);
        let mut rebuild_h = Histogram::new();
        for s in 0..=cfg.samples {
            let t0 = Instant::now();
            let rc = plan_reconstruction(
                &mut session,
                1,
                shard,
                last_step,
                erasure.total(),
                &[],
            )?
            .ok_or_else(|| anyhow!("stripes must cover the shard"))?;
            let rebuilt = reconstruct_shard(&mut session, 1, &rc, 2, &fence)
                .map_err(|e| anyhow!("bench rebuild: {e}"))?;
            ensure!(
                rebuilt.content_hash() == snap.content_hash(),
                "bench rebuild must be bit-exact"
            );
            if s > 0 {
                rebuild_h.record(t0.elapsed().as_secs_f64());
            }
        }

        // baseline 1: replica-sourced stream of the same snapshot
        let mut replica_h = Histogram::new();
        for s in 0..=cfg.samples {
            let listener = TcpListener::bind("127.0.0.1:0")?;
            let addr = listener.local_addr()?;
            let serve_snap = snap.clone();
            let serve_fence = fence.clone();
            let stream_cfg = StreamConfig {
                chunk_bytes: cfg.chunk_bytes,
                ..Default::default()
            };
            let server_t = std::thread::spawn(move || {
                let (mut conn, _) = listener.accept()?;
                serve_snapshot(&mut conn, &serve_snap, shard, 2, &serve_fence, &stream_cfg)
                    .map_err(|e| anyhow!("bench serve: {e}"))?;
                Ok::<_, anyhow::Error>(())
            });
            let t0 = Instant::now();
            let mut conn = TcpStream::connect(addr)?;
            let expect = Expect { epoch: 2, shard, step: Some(last_step) };
            let (got, _) = fetch_snapshot(&mut conn, &expect, &fence)
                .map_err(|e| anyhow!("bench fetch: {e}"))?;
            ensure!(got.content_hash() == snap.content_hash());
            if s > 0 {
                replica_h.record(t0.elapsed().as_secs_f64());
            }
            server_t.join().unwrap()?;
        }

        // baseline 2: the file-checkpoint fallback the tier avoids
        let path = tmp.join(format!("shard-{elems}.ckpt"));
        crate::checkpoint::write_snapshot(&path, &snap)?;
        let mut ckpt_h = Histogram::new();
        for s in 0..=cfg.samples {
            let t0 = Instant::now();
            let got = crate::checkpoint::read_snapshot(&path)?;
            ensure!(got.content_hash() == snap.content_hash());
            if s > 0 {
                ckpt_h.record(t0.elapsed().as_secs_f64());
            }
        }

        report.row(
            format!("{:.1}M elems", elems as f64 / 1e6),
            vec![
                ship_h.p50() * 1e3,
                reship_h.p50() * 1e3,
                rebuild_h.p50() * 1e3,
                replica_h.p50() * 1e3,
                ckpt_h.p50() * 1e3,
                shipped_mb / f64::from(cfg.samples),
            ],
        );
        drop(depots);
    }
    std::fs::remove_dir_all(&tmp).ok();
    Ok(report)
}

/// The acceptance properties `bench redundancy --assert` enforces on
/// top of the baseline ratio: steady-state overhead is bounded (the
/// delta fast path — 38-byte refreshes — must not cost more than a
/// worst-case full ship) and reconstruction stays in streaming-restore
/// territory rather than checkpoint-stall territory (the fallback it
/// beats also forfeits every step since the last checkpoint, which the
/// `replica_group_wipeout` scenario pins at zero for the stripe path).
pub fn check_report(cfg: &RedundancySweepConfig, report: &BenchReport) -> Result<()> {
    for &elems in &cfg.sizes {
        let label = format!("{:.1}M elems", elems as f64 / 1e6);
        let v = report
            .row_values(&label)
            .ok_or_else(|| anyhow!("bench report is missing row {label:?}"))?;
        ensure!(v.len() == 6, "row {label:?} has {} of 6 columns", v.len());
        let (ship, reship, rebuild, replica) = (v[0], v[1], v[2], v[3]);
        ensure!(
            ship > 0.0 && v[5] > 0.0,
            "row {label:?}: a dirty ship must take time and move bytes"
        );
        ensure!(
            reship <= ship,
            "row {label:?}: delta reship ({reship:.3} ms) must undercut a \
             full ship ({ship:.3} ms)"
        );
        ensure!(
            rebuild <= replica.max(0.1) * 20.0,
            "row {label:?}: stripe rebuild ({rebuild:.3} ms) must stay within \
             20x of a replica-sourced stream ({replica:.3} ms)"
        );
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sweep_produces_a_row_per_size_with_sane_values() {
        let cfg = RedundancySweepConfig {
            sizes: vec![12_000],
            samples: 2,
            chunk_bytes: 16 * 1024,
            ..Default::default()
        };
        let report = redundancy_sweep(&cfg).unwrap();
        let values = report.row_values("0.0M elems").expect("row must exist");
        assert_eq!(values.len(), 6);
        // ship moved bytes; reship (all refreshes) must not be slower
        // than a full ship by orders of magnitude
        assert!(values[0] > 0.0);
        assert!(values[5] > 0.0, "ship must move bytes");
    }

    #[test]
    fn check_report_flags_a_slow_delta_path() {
        let cols = [
            "ship p50 ms",
            "reship p50 ms",
            "rebuild ms",
            "replica ms",
            "ckpt ms",
            "MB shipped",
        ];
        let cfg = RedundancySweepConfig {
            sizes: vec![1_048_576],
            ..Default::default()
        };
        let mut good = BenchReport::new("redundancy", &cols);
        good.row("1.0M elems".to_string(), vec![10.0, 1.0, 8.0, 5.0, 6.0, 12.0]);
        check_report(&cfg, &good).unwrap();

        // a delta path slower than a full ship is a regression
        let mut bad = BenchReport::new("redundancy", &cols);
        bad.row("1.0M elems".to_string(), vec![10.0, 30.0, 8.0, 5.0, 6.0, 12.0]);
        assert!(check_report(&cfg, &bad).is_err());

        // a rebuild in checkpoint-stall territory is a regression
        let mut slow = BenchReport::new("redundancy", &cols);
        slow.row("1.0M elems".to_string(), vec![10.0, 1.0, 500.0, 5.0, 6.0, 12.0]);
        assert!(check_report(&cfg, &slow).is_err());
    }
}
