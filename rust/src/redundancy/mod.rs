//! The redundancy tier (DESIGN.md §16): every ZeRO shard stays
//! restorable even when its *entire* replica group dies.
//!
//! Each shard owner erasure-codes its canonical snapshot encoding into
//! `k + m` stripes ([`checkpoint::erasure`]) and streams them to `k+m`
//! peer [`StripeDepot`]s — nodes that do **not** hold the shard, plus
//! warm spares — during idle step time, over the state-stream chunk
//! grammar ([`serve_blob`]/[`fetch_blob`]: per-chunk checksums, chained
//! end hash, epoch-fenced abort). Re-shipping an unchanged stripe
//! degrades to a 38-byte hash refresh, so steady-state overhead tracks
//! the *dirty* fraction of the shard, not its size.
//!
//! Placement is advertised through the replicated store under
//! `redund/<epoch>/<tag>/<idx>` keys — epoch-fenced and pruned exactly
//! like `restore/` sources, with the crucial property that epoch `e-1`
//! survives an advance to `e`: stripes shipped during training epoch
//! `e` are still advertised while recovery runs at `e+1`. Depot
//! endpoints live under `redund/depot/<holder>`, which never parses as
//! an epoch and therefore survives pruning.
//!
//! **Advertise-after-complete**: a stripe's store advertisement is
//! written only after its depot acks a fully validated install, so an
//! in-flight transfer superseded by recovery aborts retryably
//! ([`RestoreError::Superseded`]) and can never leave a torn stripe
//! advertised.
//!
//! Recovery: when [`plan_shard_restore`] reports a shard *unsourced*
//! (its whole replica group died), [`plan_reconstruction`] checks the
//! stripe directory — any `k` of `k+m` surviving stripes at the resume
//! step make the shard recoverable — and [`reconstruct_shard`] pulls
//! them, inverts the code, and verifies the rebuilt snapshot against
//! the advertised content hash: bit-exact, zero checkpoint reads.
//! A [`WarmSpare`] pre-fetches the hottest stripes ahead of time so a
//! replacement's join skips the network restore entirely.
//!
//! [`checkpoint::erasure`]: crate::checkpoint::erasure
//! [`plan_shard_restore`]: crate::coordinator::restore::plan_shard_restore

use crate::checkpoint::erasure::{encode_stripes, reconstruct, ErasureConfig};
use crate::checkpoint::{codec, Snapshot};
use crate::comms::replication::{StoreEndpoints, StoreSession};
use crate::comms::state_stream::{
    fetch_blob, serve_blob, transfer_tag, EpochFence, Expect, RestoreError,
    RestoreResult, StreamConfig, DEFAULT_CHUNK_BYTES,
};
use crate::config::{ParallelismConfig, ShardId};
use crate::coordinator::restore::ShardReconstruction;
use crate::util::hash::{fnv1a, FNV_OFFSET};
use anyhow::{anyhow, ensure, Result};
use std::collections::HashMap;
use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Pseudo source rank naming a shard's stripe set in the transfer-tag
/// space: the max 20-bit value, which no real rank can occupy, so
/// stripe tags never collide with replica-restore tags for the same
/// shard.
pub const STRIPE_SOURCE: usize = (1 << 20) - 1;

/// Depot wire preamble: `op u8 | tag u64 | stripe u32 | epoch u64 |
/// step u64` (little-endian), optionally followed by op-specific
/// fields, then the blob grammar.
const PREAMBLE_LEN: usize = 1 + 8 + 4 + 8 + 8;
const OP_PUSH: u8 = 1;
const OP_PULL: u8 = 2;
/// Delta fast path: bump a stored stripe's (step, epoch) without
/// resending bytes, validated by the stripe hash.
const OP_REFRESH: u8 = 3;
/// Depot ack: `status u8 | current_epoch u64`.
const ACK_LEN: usize = 1 + 8;

const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);
const DEPOT_IO_TIMEOUT: Duration = Duration::from_secs(30);

fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|p| p.into_inner())
}

/// Tag under which a shard's stripes are advertised.
pub fn stripe_tag(shard: ShardId) -> u64 {
    transfer_tag(shard, STRIPE_SOURCE)
}

/// Invert [`transfer_tag`]'s shard part — depots recover the shard a
/// pushed stripe belongs to from its tag alone.
pub fn shard_of_tag(tag: u64) -> ShardId {
    ShardId {
        pp: ((tag >> 52) & 0xFFF) as usize,
        tp: ((tag >> 40) & 0xFFF) as usize,
        zero: ((tag >> 20) & 0xF_FFFF) as usize,
    }
}

/// Store key advertising stripe `idx` of `shard` at `epoch`.
pub fn stripe_meta_key(epoch: u64, shard: ShardId, idx: usize) -> String {
    format!("redund/{epoch}/{:016x}/{idx}", stripe_tag(shard))
}

/// Store key advertising a holder's depot endpoint. "depot" never
/// parses as an epoch number, so these survive epoch pruning.
pub fn depot_key(holder: usize) -> String {
    format!("redund/depot/{holder}")
}

/// Redundancy-tier parameters.
#[derive(Debug, Clone, Copy)]
pub struct RedundancyConfig {
    pub erasure: ErasureConfig,
    pub chunk_bytes: usize,
    /// Deterministic per-chunk delay for tests that must land an epoch
    /// bump mid-stripe-transfer.
    pub throttle: Option<Duration>,
}

impl Default for RedundancyConfig {
    fn default() -> Self {
        RedundancyConfig {
            erasure: ErasureConfig::default(),
            chunk_bytes: DEFAULT_CHUNK_BYTES,
            throttle: None,
        }
    }
}

impl RedundancyConfig {
    pub fn total(&self) -> usize {
        self.erasure.total()
    }

    fn stream_cfg(&self) -> StreamConfig {
        StreamConfig {
            chunk_bytes: self.chunk_bytes,
            throttle: self.throttle,
            ..Default::default()
        }
    }
}

/// Per-stripe advertisement: everything a reconstructing (or
/// prefetching) node needs to validate what it pulls. Fixed 56-byte
/// little-endian layout.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct StripeMeta {
    /// Training step the stripe set encodes.
    pub step: u64,
    pub k: u32,
    pub m: u32,
    /// Length of the encoded snapshot the stripes reconstruct.
    pub orig_len: u64,
    pub stripe_len: u64,
    /// fnv1a of this stripe's bytes — pulled stripes are verified
    /// against it before entering the decode matrix.
    pub stripe_hash: u64,
    /// Content hash of the snapshot the stripes encode — the bit-exact
    /// acceptance check after reconstruction.
    pub snap_hash: u64,
    /// Holder id whose depot stores the stripe.
    pub holder: u64,
}

pub const STRIPE_META_LEN: usize = 56;

impl StripeMeta {
    pub fn encode(&self) -> [u8; STRIPE_META_LEN] {
        let mut out = [0u8; STRIPE_META_LEN];
        let mut pos = 0;
        let mut put = |b: &[u8]| {
            out[pos..pos + b.len()].copy_from_slice(b);
            pos += b.len();
        };
        put(&self.step.to_le_bytes());
        put(&self.k.to_le_bytes());
        put(&self.m.to_le_bytes());
        put(&self.orig_len.to_le_bytes());
        put(&self.stripe_len.to_le_bytes());
        put(&self.stripe_hash.to_le_bytes());
        put(&self.snap_hash.to_le_bytes());
        put(&self.holder.to_le_bytes());
        out
    }

    pub fn decode(buf: &[u8]) -> Result<StripeMeta> {
        ensure!(
            buf.len() == STRIPE_META_LEN,
            "stripe meta must be {STRIPE_META_LEN} bytes, got {}",
            buf.len()
        );
        let u32_at = |at: usize| u32::from_le_bytes(buf[at..at + 4].try_into().unwrap());
        let u64_at = |at: usize| u64::from_le_bytes(buf[at..at + 8].try_into().unwrap());
        Ok(StripeMeta {
            step: u64_at(0),
            k: u32_at(8),
            m: u32_at(12),
            orig_len: u64_at(16),
            stripe_len: u64_at(24),
            stripe_hash: u64_at(32),
            snap_hash: u64_at(40),
            holder: u64_at(48),
        })
    }
}

/// Deterministic stripe placement: the `total` holders for a shard's
/// stripes, drawn from ranks that do NOT hold the shard (a holder
/// dying with the replica group would defeat the tier) plus warm
/// spares (ids `world_size..world_size + spares`). The start offset
/// rotates with the shard coordinates so depots share load across
/// shards. Stripe `i` lives on `holders[i]`.
pub fn stripe_holders(
    par: &ParallelismConfig,
    shard: ShardId,
    spares: usize,
    total: usize,
) -> Result<Vec<usize>> {
    let mut candidates: Vec<usize> = (0..par.world_size())
        .filter(|&r| par.shard_id(r) != shard)
        .collect();
    candidates.extend(par.world_size()..par.world_size() + spares);
    ensure!(
        candidates.len() >= total,
        "need {total} stripe holders for shard {shard:?}, only {} candidates \
         (world {} + {spares} spares)",
        candidates.len(),
        par.world_size()
    );
    let start = (shard.pp + shard.tp * 3 + shard.zero * 7) % candidates.len();
    Ok((0..total).map(|i| candidates[(start + i) % candidates.len()]).collect())
}

#[derive(Debug, Clone)]
struct StoredStripe {
    epoch: u64,
    step: u64,
    data: Vec<u8>,
}

/// An in-memory stripe store serving the depot wire protocol on an
/// ephemeral listener: PUSH installs a fully validated stripe (blob
/// grammar, fenced), REFRESH bumps a stored stripe's version when the
/// sender proves (by hash) the bytes are unchanged, PULL streams a
/// stored stripe back at the requester's epoch. Partial transfers are
/// discarded, never installed.
pub struct StripeDepot {
    addr: SocketAddr,
    stripes: Arc<Mutex<HashMap<(u64, u32), StoredStripe>>>,
    fence: EpochFence,
    stop: Arc<AtomicBool>,
    accept_thread: Option<std::thread::JoinHandle<()>>,
}

impl StripeDepot {
    pub fn start(fence: EpochFence, chunk_bytes: usize) -> Result<StripeDepot> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stripes: Arc<Mutex<HashMap<(u64, u32), StoredStripe>>> =
            Arc::new(Mutex::new(HashMap::new()));
        let stop = Arc::new(AtomicBool::new(false));
        let t = {
            let (stripes, fence, stop) = (stripes.clone(), fence.clone(), stop.clone());
            std::thread::Builder::new()
                .name("stripe-depot".into())
                .spawn(move || {
                    while !stop.load(Ordering::Relaxed) {
                        match listener.accept() {
                            Ok((conn, _)) => {
                                let (stripes, fence) = (stripes.clone(), fence.clone());
                                std::thread::Builder::new()
                                    .name("stripe-depot-conn".into())
                                    .spawn(move || {
                                        if let Err(e) = Self::handle(
                                            conn,
                                            &stripes,
                                            &fence,
                                            chunk_bytes,
                                        ) {
                                            crate::telemetry::log::debug("redund", || {
                                                format!("depot conn ended: {e}")
                                            });
                                        }
                                    })
                                    .ok();
                            }
                            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                                std::thread::sleep(Duration::from_millis(1));
                            }
                            Err(_) => break,
                        }
                    }
                })
                .map_err(|e| anyhow!("spawn depot accept thread: {e}"))?
        };
        Ok(StripeDepot {
            addr,
            stripes,
            fence,
            stop,
            accept_thread: Some(t),
        })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Number of fully installed stripes.
    pub fn stripe_count(&self) -> usize {
        lock(&self.stripes).len()
    }

    /// True iff the depot holds a complete stripe matching `hash` —
    /// the no-torn-stripe invariant tests assert through this.
    pub fn holds(&self, tag: u64, idx: u32, hash: u64) -> bool {
        lock(&self.stripes)
            .get(&(tag, idx))
            .map(|s| fnv1a(&s.data, FNV_OFFSET) == hash)
            .unwrap_or(false)
    }

    /// Advertise this depot's endpoint in the store under `holder`'s
    /// depot key.
    pub fn advertise(&self, session: &mut StoreSession, holder: usize) -> Result<()> {
        session.set(&depot_key(holder), self.addr.to_string().as_bytes())
    }

    fn handle(
        mut conn: TcpStream,
        stripes: &Mutex<HashMap<(u64, u32), StoredStripe>>,
        fence: &EpochFence,
        chunk_bytes: usize,
    ) -> Result<()> {
        conn.set_read_timeout(Some(DEPOT_IO_TIMEOUT)).ok();
        conn.set_write_timeout(Some(DEPOT_IO_TIMEOUT)).ok();
        conn.set_nodelay(true).ok();
        let mut pre = [0u8; PREAMBLE_LEN];
        conn.read_exact(&mut pre)?;
        let op = pre[0];
        let tag = u64::from_le_bytes(pre[1..9].try_into().unwrap());
        let idx = u32::from_le_bytes(pre[9..13].try_into().unwrap());
        let epoch = u64::from_le_bytes(pre[13..21].try_into().unwrap());
        let step = u64::from_le_bytes(pre[21..29].try_into().unwrap());
        match op {
            OP_PUSH => {
                let expect = Expect {
                    epoch,
                    shard: shard_of_tag(tag),
                    step: Some(step),
                };
                match fetch_blob(&mut conn, &expect, fence) {
                    Ok((_, data, _)) => {
                        // install only while the pushing epoch is still
                        // current: a bump that landed after the last
                        // chunk must not resurrect a pre-failure stripe
                        if fence.current() == epoch {
                            lock(stripes)
                                .insert((tag, idx), StoredStripe { epoch, step, data });
                            Self::ack(&mut conn, 1, fence.current());
                        } else {
                            Self::ack(&mut conn, 0, fence.current());
                        }
                    }
                    Err(RestoreError::Superseded { current }) => {
                        Self::ack(&mut conn, 0, current);
                    }
                    Err(RestoreError::Fatal(e)) => return Err(e),
                }
            }
            OP_REFRESH => {
                let mut h = [0u8; 8];
                conn.read_exact(&mut h)?;
                let hash = u64::from_le_bytes(h);
                let mut g = lock(stripes);
                let ok = match g.get_mut(&(tag, idx)) {
                    Some(s)
                        if fnv1a(&s.data, FNV_OFFSET) == hash
                            && fence.current() == epoch =>
                    {
                        s.step = step;
                        s.epoch = epoch;
                        true
                    }
                    _ => false,
                };
                drop(g);
                Self::ack(&mut conn, u8::from(ok), fence.current());
            }
            OP_PULL => {
                let stored = lock(stripes).get(&(tag, idx)).cloned();
                match stored {
                    None => Self::ack(&mut conn, 0, fence.current()),
                    Some(s) => {
                        Self::ack(&mut conn, 1, fence.current());
                        // serve at the *requester's* epoch: recovery
                        // runs one epoch past the shipping epoch, and
                        // a further bump still aborts retryably
                        let cfg = StreamConfig {
                            chunk_bytes,
                            ..Default::default()
                        };
                        serve_blob(
                            &mut conn,
                            &s.data,
                            s.step,
                            shard_of_tag(tag),
                            epoch,
                            fence,
                            &cfg,
                        )
                        .map_err(|e| anyhow!("depot pull serve: {e}"))?;
                    }
                }
            }
            other => return Err(anyhow!("unknown depot op {other}")),
        }
        Ok(())
    }

    fn ack(conn: &mut TcpStream, status: u8, current: u64) {
        let mut buf = [0u8; ACK_LEN];
        buf[0] = status;
        buf[1..9].copy_from_slice(&current.to_le_bytes());
        // the peer may already be gone (it aborted the transfer); a
        // failed ack write is its problem, not the depot's
        conn.write_all(&buf).ok();
        conn.flush().ok();
    }

    pub fn shutdown(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(t) = self.accept_thread.take() {
            let _ = t.join();
        }
    }
}

impl Drop for StripeDepot {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn preamble(op: u8, tag: u64, idx: u32, epoch: u64, step: u64) -> [u8; PREAMBLE_LEN] {
    let mut pre = [0u8; PREAMBLE_LEN];
    pre[0] = op;
    pre[1..9].copy_from_slice(&tag.to_le_bytes());
    pre[9..13].copy_from_slice(&idx.to_le_bytes());
    pre[13..21].copy_from_slice(&epoch.to_le_bytes());
    pre[21..29].copy_from_slice(&step.to_le_bytes());
    pre
}

fn dial_depot(addr: SocketAddr) -> RestoreResult<Box<dyn crate::comms::link::Link>> {
    let link = crate::comms::link::default_dialer()
        .dial(addr, CONNECT_TIMEOUT)
        .map_err(|e| RestoreError::Fatal(anyhow!("dial depot {addr}: {e}")))?;
    link.set_read_timeout(Some(DEPOT_IO_TIMEOUT)).ok();
    link.set_nodelay(true).ok();
    Ok(link)
}

fn read_ack<R: Read>(r: &mut R) -> RestoreResult<(u8, u64)> {
    let mut buf = [0u8; ACK_LEN];
    r.read_exact(&mut buf)
        .map_err(|e| RestoreError::Fatal(anyhow!("depot ack: {e}")))?;
    Ok((buf[0], u64::from_le_bytes(buf[1..9].try_into().unwrap())))
}

/// Push one stripe to a depot under the fence. Retryably superseded if
/// the epoch moves mid-transfer or the depot declines the install.
fn push_stripe(
    addr: SocketAddr,
    tag: u64,
    idx: u32,
    stripe: &[u8],
    step: u64,
    epoch: u64,
    fence: &EpochFence,
    cfg: &StreamConfig,
) -> RestoreResult<()> {
    let mut link = dial_depot(addr)?;
    link.write_all(&preamble(OP_PUSH, tag, idx, epoch, step))
        .map_err(|e| RestoreError::Fatal(e.into()))?;
    serve_blob(&mut link, stripe, step, shard_of_tag(tag), epoch, fence, cfg)?;
    match read_ack(&mut link)? {
        (1, _) => Ok(()),
        (_, current) => Err(RestoreError::Superseded { current }),
    }
}

/// Try the hash-refresh fast path; `Ok(true)` means the depot accepted
/// the version bump and no bytes need to move.
fn refresh_stripe(
    addr: SocketAddr,
    tag: u64,
    idx: u32,
    hash: u64,
    step: u64,
    epoch: u64,
) -> RestoreResult<bool> {
    let mut link = dial_depot(addr)?;
    let mut msg = Vec::with_capacity(PREAMBLE_LEN + 8);
    msg.extend_from_slice(&preamble(OP_REFRESH, tag, idx, epoch, step));
    msg.extend_from_slice(&hash.to_le_bytes());
    link.write_all(&msg).map_err(|e| RestoreError::Fatal(e.into()))?;
    Ok(read_ack(&mut link)?.0 == 1)
}

/// Pull one stripe from a depot at the requester's `epoch`, verifying
/// the blob grammar end to end.
pub fn pull_stripe(
    addr: SocketAddr,
    tag: u64,
    idx: u32,
    step: u64,
    epoch: u64,
    fence: &EpochFence,
) -> RestoreResult<Vec<u8>> {
    let mut link = dial_depot(addr)?;
    link.write_all(&preamble(OP_PULL, tag, idx, epoch, step))
        .map_err(|e| RestoreError::Fatal(e.into()))?;
    match read_ack(&mut link)? {
        (1, _) => {}
        (_, _) => {
            return Err(RestoreError::Fatal(anyhow!(
                "depot {addr} does not hold stripe {idx} of tag {tag:016x}"
            )))
        }
    }
    let expect = Expect { epoch, shard: shard_of_tag(tag), step: Some(step) };
    let (_, data, _) = fetch_blob(&mut link, &expect, fence)?;
    Ok(data)
}

/// Accounting for one [`StripeShipper::ship`] round.
#[derive(Debug, Clone, Copy, Default)]
pub struct ShipStats {
    /// Stripes whose bytes crossed the wire.
    pub shipped: usize,
    /// Stripes that degraded to the hash-refresh fast path.
    pub skipped: usize,
    pub bytes: u64,
    pub wall_s: f64,
}

/// The owner-side shipper: erasure-codes a shard snapshot, pushes
/// dirty stripes to their holders' depots (unchanged stripes refresh
/// by hash), and advertises each stripe in the store only after its
/// depot acked the install.
pub struct StripeShipper {
    cfg: RedundancyConfig,
    shard: ShardId,
    /// `(holder id, depot addr)` per stripe index.
    holders: Vec<(usize, SocketAddr)>,
    fence: EpochFence,
    session: StoreSession,
    /// fnv1a of the last successfully placed version of each stripe.
    last_hashes: Vec<Option<u64>>,
    last_step: Option<u64>,
}

impl StripeShipper {
    pub fn new(
        store: &StoreEndpoints,
        cfg: RedundancyConfig,
        shard: ShardId,
        holders: Vec<(usize, SocketAddr)>,
        fence: EpochFence,
    ) -> Result<StripeShipper> {
        cfg.erasure.validate()?;
        ensure!(
            holders.len() == cfg.total(),
            "shard {shard:?} needs {} stripe holders, got {}",
            cfg.total(),
            holders.len()
        );
        let session = StoreSession::try_connect(store)?;
        let last_hashes = vec![None; holders.len()];
        Ok(StripeShipper {
            cfg,
            shard,
            holders,
            fence,
            session,
            last_hashes,
            last_step: None,
        })
    }

    /// Last step whose stripes are fully placed and advertised — the
    /// worker derives the `redund.stripe_lag` gauge from this.
    pub fn last_shipped_step(&self) -> Option<u64> {
        self.last_step
    }

    /// Encode `snap` and place its stripes at `epoch`. Sequential per
    /// stripe: push (or refresh) to the holder's depot, then advertise
    /// the stripe meta — so an abort anywhere leaves only complete,
    /// advertised stripes behind. Retryably superseded on any epoch
    /// bump; the caller replans at the new epoch.
    pub fn ship(&mut self, snap: &Snapshot, epoch: u64) -> RestoreResult<ShipStats> {
        let t0 = Instant::now();
        let tele = crate::telemetry::global();
        let encoded = codec::encode_snapshot(snap);
        let snap_hash = snap.content_hash();
        let stripes = encode_stripes(&encoded, &self.cfg.erasure)
            .map_err(RestoreError::Fatal)?;
        let stream_cfg = self.cfg.stream_cfg();
        let tag = stripe_tag(self.shard);
        let mut stats = ShipStats::default();
        for (idx, stripe) in stripes.iter().enumerate() {
            let current = self.fence.current();
            if current > epoch {
                return Err(RestoreError::Superseded { current });
            }
            let (holder, addr) = self.holders[idx];
            let hash = fnv1a(stripe, FNV_OFFSET);
            let refreshed = self.last_hashes[idx] == Some(hash)
                && refresh_stripe(addr, tag, idx as u32, hash, snap.step, epoch)?;
            if refreshed {
                stats.skipped += 1;
                tele.inc("redund.stripes_skipped");
            } else {
                push_stripe(
                    addr,
                    tag,
                    idx as u32,
                    stripe,
                    snap.step,
                    epoch,
                    &self.fence,
                    &stream_cfg,
                )?;
                stats.shipped += 1;
                stats.bytes += stripe.len() as u64;
                tele.inc("redund.stripes_shipped");
                tele.add("redund.bytes_shipped", stripe.len() as u64);
            }
            self.last_hashes[idx] = Some(hash);
            // advertise-after-complete: the meta key appears only once
            // the depot holds the full validated stripe
            let meta = StripeMeta {
                step: snap.step,
                k: self.cfg.erasure.k as u32,
                m: self.cfg.erasure.m as u32,
                orig_len: encoded.len() as u64,
                stripe_len: stripe.len() as u64,
                stripe_hash: hash,
                snap_hash,
                holder: holder as u64,
            };
            self.session
                .set(&stripe_meta_key(epoch, self.shard, idx), &meta.encode())
                .map_err(RestoreError::Fatal)?;
        }
        self.last_step = Some(snap.step);
        tele.gauge("redund.stripe_lag").set(0);
        stats.wall_s = t0.elapsed().as_secs_f64();
        Ok(stats)
    }
}

/// Check the stripe directory for a shard the replica planner reported
/// unsourced: returns a reconstruction schedule when at least `k`
/// stripes advertised at `ad_epoch` carry the resume `step` and have a
/// known depot endpoint. `total` bounds the stripe indices probed
/// (the configured `k + m`).
pub fn plan_reconstruction(
    session: &mut StoreSession,
    ad_epoch: u64,
    shard: ShardId,
    step: u64,
    total: usize,
    targets: &[usize],
) -> Result<Option<ShardReconstruction>> {
    let mut k = 0u32;
    let mut m = 0u32;
    let mut stripes = Vec::new();
    for idx in 0..total {
        let Some(raw) = session.get(&stripe_meta_key(ad_epoch, shard, idx))? else {
            continue;
        };
        let meta = StripeMeta::decode(&raw)?;
        if meta.step != step {
            continue; // stale stripe from an earlier ship
        }
        if k == 0 {
            k = meta.k;
            m = meta.m;
        } else if meta.k != k || meta.m != m {
            continue; // shape mismatch: stripe from a different config
        }
        let Some(addr_raw) = session.get(&depot_key(meta.holder as usize))? else {
            continue; // holder never advertised a depot
        };
        let addr: SocketAddr = std::str::from_utf8(&addr_raw)?.parse()?;
        stripes.push((idx, addr));
    }
    if k == 0 || stripes.len() < k as usize {
        return Ok(None);
    }
    Ok(Some(ShardReconstruction {
        shard,
        step,
        k: k as usize,
        m: m as usize,
        stripes,
        targets: targets.to_vec(),
    }))
}

/// Offer every unsourced shard of `plan` to the stripe directory —
/// the coordinator's one-call bridge from replica planning to the
/// redundancy fallback.
pub fn cover_plan(
    session: &mut StoreSession,
    ad_epoch: u64,
    total: usize,
    plan: &mut crate::coordinator::restore::RestorePlan,
) -> Result<()> {
    let mut err = None;
    plan.cover_unsourced(|shard, step, targets| {
        match plan_reconstruction(session, ad_epoch, shard, step, total, targets) {
            Ok(rc) => rc,
            Err(e) => {
                err.get_or_insert(e);
                None
            }
        }
    });
    match err {
        Some(e) => Err(e),
        None => Ok(()),
    }
}

/// Execute one [`ShardReconstruction`]: pull any `k` of its advertised
/// stripes (each verified against its advertised hash), invert the
/// erasure code, decode the snapshot, and verify it bit-exact against
/// the advertised content hash. `recovery_epoch` fences the pulls;
/// dead depots are skipped as long as `k` survive.
pub fn reconstruct_shard(
    session: &mut StoreSession,
    ad_epoch: u64,
    rc: &ShardReconstruction,
    recovery_epoch: u64,
    fence: &EpochFence,
) -> RestoreResult<Snapshot> {
    let tag = stripe_tag(rc.shard);
    let total = rc.k + rc.m;
    let mut slots: Vec<Option<Vec<u8>>> = vec![None; total];
    let mut have = 0usize;
    let mut orig_len = None;
    let mut snap_hash = None;
    for &(idx, addr) in &rc.stripes {
        if have >= rc.k {
            break;
        }
        if idx >= total {
            continue;
        }
        let Some(raw) = session
            .get(&stripe_meta_key(ad_epoch, rc.shard, idx))
            .map_err(RestoreError::Fatal)?
        else {
            continue;
        };
        let meta = StripeMeta::decode(&raw).map_err(RestoreError::Fatal)?;
        if meta.step != rc.step {
            continue;
        }
        match pull_stripe(addr, tag, idx as u32, rc.step, recovery_epoch, fence) {
            Ok(data) => {
                if fnv1a(&data, FNV_OFFSET) != meta.stripe_hash {
                    continue; // corrupt or stale depot copy: try others
                }
                orig_len = Some(meta.orig_len as usize);
                snap_hash = Some(meta.snap_hash);
                slots[idx] = Some(data);
                have += 1;
            }
            Err(e @ RestoreError::Superseded { .. }) => return Err(e),
            Err(RestoreError::Fatal(_)) => continue, // dead depot: try others
        }
    }
    let (Some(orig_len), Some(snap_hash)) = (orig_len, snap_hash) else {
        return Err(RestoreError::Fatal(anyhow!(
            "no usable stripes for shard {:?} at step {}",
            rc.shard,
            rc.step
        )));
    };
    if have < rc.k {
        return Err(RestoreError::Fatal(anyhow!(
            "only {have} of the required {} stripes for shard {:?} survive",
            rc.k,
            rc.shard
        )));
    }
    let cfg = ErasureConfig { k: rc.k, m: rc.m };
    let encoded = reconstruct(&slots, &cfg, orig_len).map_err(RestoreError::Fatal)?;
    let snap = codec::decode_snapshot(&encoded).map_err(RestoreError::Fatal)?;
    if snap.step != rc.step {
        return Err(RestoreError::Fatal(anyhow!(
            "reconstructed snapshot is at step {}, expected {}",
            snap.step,
            rc.step
        )));
    }
    if snap.content_hash() != snap_hash {
        return Err(RestoreError::Fatal(anyhow!(
            "reconstructed shard {:?} fails the content-hash check",
            rc.shard
        )));
    }
    crate::telemetry::global().inc("redund.reconstructions");
    Ok(snap)
}

/// A warm spare's stripe cache: during idle time the spare pre-fetches
/// the hottest stripes (the latest advertised set per shard), so that
/// when it replaces a dead node the shard rebuild runs entirely from
/// local memory — zero restore-time network fetches, zero checkpoint
/// reads.
#[derive(Default)]
pub struct WarmSpare {
    cache: HashMap<(u64, u32), (StripeMeta, Vec<u8>)>,
}

impl WarmSpare {
    pub fn new() -> WarmSpare {
        WarmSpare::default()
    }

    pub fn cached_stripes(&self) -> usize {
        self.cache.len()
    }

    /// Pull every advertised stripe of `shard` at `ad_epoch` into the
    /// local cache (already-cached identical versions are skipped).
    /// Returns how many stripes were fetched.
    pub fn prefetch(
        &mut self,
        session: &mut StoreSession,
        ad_epoch: u64,
        shard: ShardId,
        total: usize,
        fence: &EpochFence,
    ) -> Result<usize> {
        let tag = stripe_tag(shard);
        let mut fetched = 0;
        for idx in 0..total {
            let Some(raw) = session.get(&stripe_meta_key(ad_epoch, shard, idx))? else {
                continue;
            };
            let meta = StripeMeta::decode(&raw)?;
            if let Some((cached, _)) = self.cache.get(&(tag, idx as u32)) {
                if cached.stripe_hash == meta.stripe_hash && cached.step == meta.step {
                    continue;
                }
            }
            let Some(addr_raw) = session.get(&depot_key(meta.holder as usize))? else {
                continue;
            };
            let addr: SocketAddr = std::str::from_utf8(&addr_raw)?.parse()?;
            let data = pull_stripe(
                addr,
                tag,
                idx as u32,
                meta.step,
                fence.current(),
                fence,
            )
            .map_err(|e| anyhow!("prefetch stripe {idx}: {e}"))?;
            ensure!(
                fnv1a(&data, FNV_OFFSET) == meta.stripe_hash,
                "prefetched stripe {idx} fails its hash check"
            );
            self.cache.insert((tag, idx as u32), (meta, data));
            fetched += 1;
        }
        Ok(fetched)
    }

    /// Rebuild `shard` at `step` from the local cache alone — the
    /// replacement-join fast path. Fails (so the caller falls back to
    /// networked reconstruction) when fewer than `k` cached stripes
    /// match the step.
    pub fn recover_local(&self, shard: ShardId, step: u64) -> Result<Snapshot> {
        let tag = stripe_tag(shard);
        let mut shape: Option<(usize, usize, usize, u64)> = None;
        for ((t, _), (meta, _)) in &self.cache {
            if *t == tag && meta.step == step {
                shape = Some((
                    meta.k as usize,
                    meta.m as usize,
                    meta.orig_len as usize,
                    meta.snap_hash,
                ));
                break;
            }
        }
        let Some((k, m, orig_len, snap_hash)) = shape else {
            anyhow::bail!("no cached stripes for shard {shard:?} at step {step}");
        };
        let mut slots: Vec<Option<Vec<u8>>> = vec![None; k + m];
        for idx in 0..k + m {
            if let Some((meta, data)) = self.cache.get(&(tag, idx as u32)) {
                if meta.step == step {
                    slots[idx] = Some(data.clone());
                }
            }
        }
        let cfg = ErasureConfig { k, m };
        let encoded = reconstruct(&slots, &cfg, orig_len)?;
        let snap = codec::decode_snapshot(&encoded)?;
        ensure!(
            snap.content_hash() == snap_hash,
            "locally rebuilt shard {shard:?} fails the content-hash check"
        );
        crate::telemetry::global().inc("redund.reconstructions");
        Ok(snap)
    }
}

pub mod bench;

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comms::tcp_store::TcpStoreServer;
    use crate::coordinator::restore::synthetic_snapshot;

    fn shard() -> ShardId {
        ShardId { pp: 0, tp: 0, zero: 1 }
    }

    /// Store + `total` depots + advertised endpoints + a shipper for
    /// one shard, all under one fence — the tier's test fixture.
    struct Fixture {
        server: TcpStoreServer,
        fence: EpochFence,
        depots: Vec<StripeDepot>,
        holders: Vec<(usize, SocketAddr)>,
        cfg: RedundancyConfig,
    }

    impl Fixture {
        fn new(cfg: RedundancyConfig) -> Fixture {
            let server = TcpStoreServer::start().unwrap();
            let fence = EpochFence::new(1);
            let mut session = StoreSession::try_connect(&server.endpoints()).unwrap();
            let mut depots = Vec::new();
            let mut holders = Vec::new();
            for i in 0..cfg.total() {
                let d = StripeDepot::start(fence.clone(), cfg.chunk_bytes).unwrap();
                let holder = 100 + i;
                d.advertise(&mut session, holder).unwrap();
                holders.push((holder, d.addr()));
                depots.push(d);
            }
            Fixture { server, fence, depots, holders, cfg }
        }

        fn session(&self) -> StoreSession {
            StoreSession::try_connect(&self.server.endpoints()).unwrap()
        }

        fn shipper(&self) -> StripeShipper {
            StripeShipper::new(
                &self.server.endpoints(),
                self.cfg,
                shard(),
                self.holders.clone(),
                self.fence.clone(),
            )
            .unwrap()
        }
    }

    #[test]
    fn stripe_tags_invert_and_stay_clear_of_replica_tags() {
        let s = ShardId { pp: 3, tp: 5, zero: 1000 };
        assert_eq!(shard_of_tag(stripe_tag(s)), s);
        for source in 0..64 {
            assert_ne!(stripe_tag(s), transfer_tag(s, source));
        }
    }

    #[test]
    fn meta_roundtrips_and_rejects_bad_lengths() {
        let meta = StripeMeta {
            step: 42,
            k: 2,
            m: 1,
            orig_len: 123_456,
            stripe_len: 61_728,
            stripe_hash: 0xDEAD_BEEF,
            snap_hash: 0xFEED_FACE,
            holder: 7,
        };
        assert_eq!(StripeMeta::decode(&meta.encode()).unwrap(), meta);
        assert!(StripeMeta::decode(&meta.encode()[..40]).is_err());
        assert!(StripeMeta::decode(&[0u8; STRIPE_META_LEN + 1]).is_err());
    }

    #[test]
    fn placement_avoids_the_shard_group_and_uses_spares() {
        let par = ParallelismConfig::dp(4).with_zero(2);
        // shard zero=1 is held by ranks {1, 3}: holders must come from
        // {0, 2} plus the spares
        let s = ShardId { pp: 0, tp: 0, zero: 1 };
        let holders = stripe_holders(&par, s, 1, 3).unwrap();
        assert_eq!(holders.len(), 3);
        for h in &holders {
            assert!(![1usize, 3].contains(h), "holder {h} is in the shard group");
            assert!(*h < 5, "holder {h} out of range");
        }
        let mut uniq = holders.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 3, "holders must be distinct: {holders:?}");
        // deterministic
        assert_eq!(holders, stripe_holders(&par, s, 1, 3).unwrap());
        // not enough candidates without spares
        assert!(stripe_holders(&par, s, 0, 3).is_err());
    }

    #[test]
    fn ship_then_reconstruct_after_whole_group_death_is_bit_exact() {
        let fx = Fixture::new(RedundancyConfig {
            chunk_bytes: 8 * 1024,
            ..Default::default()
        });
        let snap = synthetic_snapshot(7, 9_000);
        let mut shipper = fx.shipper();
        let stats = shipper.ship(&snap, 1).unwrap();
        assert_eq!(stats.shipped, 3);
        assert_eq!(stats.skipped, 0);
        assert!(stats.bytes > 0);
        assert_eq!(shipper.last_shipped_step(), Some(7));

        // the whole replica group dies; recovery runs at epoch 2 with
        // the stripes advertised at epoch 1
        let mut session = fx.session();
        session.advance_epoch(2).unwrap();
        fx.fence.advance(2);
        let rc = plan_reconstruction(&mut session, 1, shard(), 7, 3, &[1, 3])
            .unwrap()
            .expect("stripes must cover the dead shard");
        assert_eq!(rc.k, 2);
        assert_eq!(rc.stripes.len(), 3);
        assert_eq!(rc.targets, vec![1, 3]);
        let rebuilt = reconstruct_shard(&mut session, 1, &rc, 2, &fx.fence).unwrap();
        assert_eq!(rebuilt.step, 7);
        assert_eq!(rebuilt.content_hash(), snap.content_hash(), "must be bit-exact");
    }

    #[test]
    fn reconstruction_survives_a_dead_depot_but_not_two() {
        let mut fx = Fixture::new(RedundancyConfig {
            chunk_bytes: 8 * 1024,
            ..Default::default()
        });
        let snap = synthetic_snapshot(4, 6_000);
        fx.shipper().ship(&snap, 1).unwrap();
        let mut session = fx.session();
        session.advance_epoch(2).unwrap();
        fx.fence.advance(2);
        let rc = plan_reconstruction(&mut session, 1, shard(), 4, 3, &[1])
            .unwrap()
            .unwrap();
        // k=2, m=1: losing one depot still reconstructs...
        fx.depots.remove(0);
        let rebuilt = reconstruct_shard(&mut session, 1, &rc, 2, &fx.fence).unwrap();
        assert_eq!(rebuilt.content_hash(), snap.content_hash());
        // ...losing a second one cannot
        fx.depots.remove(0);
        let err = reconstruct_shard(&mut session, 1, &rc, 2, &fx.fence).unwrap_err();
        assert!(!err.retryable(), "{err}");
    }

    #[test]
    fn unchanged_stripes_degrade_to_hash_refreshes() {
        let fx = Fixture::new(RedundancyConfig {
            chunk_bytes: 8 * 1024,
            ..Default::default()
        });
        let mut shipper = fx.shipper();
        let snap = synthetic_snapshot(3, 6_000);
        let first = shipper.ship(&snap, 1).unwrap();
        assert_eq!((first.shipped, first.skipped), (3, 0));
        // identical snapshot: every stripe refreshes, zero bytes move
        let second = shipper.ship(&snap, 1).unwrap();
        assert_eq!((second.shipped, second.skipped), (0, 3));
        assert_eq!(second.bytes, 0);
        // a genuinely new step dirties at least the header-bearing
        // data stripe and every parity stripe, but identical tensor
        // bytes keep some stripe clean
        let next = Snapshot { step: 4, tensors: snap.tensors.clone() };
        let third = shipper.ship(&next, 1).unwrap();
        assert!(third.shipped >= 1, "{third:?}");
        assert!(third.skipped >= 1, "{third:?}");
        // the refreshed directory still reconstructs the new step
        let mut session = fx.session();
        let rc = plan_reconstruction(&mut session, 1, shard(), 4, 3, &[])
            .unwrap()
            .unwrap();
        let rebuilt = reconstruct_shard(&mut session, 1, &rc, 1, &fx.fence).unwrap();
        assert_eq!(rebuilt.content_hash(), next.content_hash());
    }

    #[test]
    fn mid_transfer_epoch_bump_aborts_retryably_with_no_torn_stripe() {
        // satellite 4: a redundancy stream superseded by recovery must
        // abort retryably and never leave a torn stripe advertised
        let fx = Fixture::new(RedundancyConfig {
            chunk_bytes: 4 * 1024,
            throttle: Some(Duration::from_millis(2)),
            ..Default::default()
        });
        let snap = synthetic_snapshot(9, 60_000); // ~240 KB encoded
        let mut shipper = fx.shipper();
        let bump_fence = fx.fence.clone();
        let mut bump_session = fx.session();
        let bumper = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(15));
            bump_session.advance_epoch(2).unwrap();
            bump_fence.advance(2);
        });
        let err = shipper.ship(&snap, 1).unwrap_err();
        bumper.join().unwrap();
        assert!(err.retryable(), "mid-transfer bump must be retryable: {err}");

        // invariant: every advertised stripe meta is backed by a
        // complete, hash-matching stripe in its depot
        let mut session = fx.session();
        let tag = stripe_tag(shard());
        let mut advertised = 0;
        for idx in 0..3usize {
            let Some(raw) = session.get(&stripe_meta_key(1, shard(), idx)).unwrap()
            else {
                continue;
            };
            advertised += 1;
            let meta = StripeMeta::decode(&raw).unwrap();
            let held = fx.depots.iter().any(|d| {
                d.holds(tag, idx as u32, meta.stripe_hash)
            });
            assert!(held, "advertised stripe {idx} is torn or missing in depots");
        }
        assert!(advertised < 3, "the aborted stripe must not be advertised");
    }

    #[test]
    fn warm_spare_recovers_locally_after_every_depot_died() {
        let mut fx = Fixture::new(RedundancyConfig {
            chunk_bytes: 8 * 1024,
            ..Default::default()
        });
        let snap = synthetic_snapshot(11, 6_000);
        fx.shipper().ship(&snap, 1).unwrap();
        let mut spare = WarmSpare::new();
        let mut session = fx.session();
        let fetched = spare
            .prefetch(&mut session, 1, shard(), 3, &fx.fence)
            .unwrap();
        assert_eq!(fetched, 3);
        // re-prefetching an unchanged set is free
        assert_eq!(
            spare.prefetch(&mut session, 1, shard(), 3, &fx.fence).unwrap(),
            0
        );
        // every depot dies; the spare still rebuilds from local cache
        fx.depots.clear();
        let rebuilt = spare.recover_local(shard(), 11).unwrap();
        assert_eq!(rebuilt.content_hash(), snap.content_hash());
        // a step it never cached is a clean error
        assert!(spare.recover_local(shard(), 12).is_err());
    }

    #[test]
    fn cover_plan_bridges_unsourced_shards_to_the_stripe_directory() {
        use crate::coordinator::restore::plan_shard_restore;
        let fx = Fixture::new(RedundancyConfig {
            chunk_bytes: 8 * 1024,
            ..Default::default()
        });
        let par = ParallelismConfig::dp(4).with_zero(2);
        let snap = synthetic_snapshot(6, 6_000);
        fx.shipper().ship(&snap, 1).unwrap();
        // ranks {1, 3} (the whole zero=1 group) die at step 6
        let mut plan = plan_shard_restore(&par, &[(0, 6), (2, 6)], &[1, 3]);
        assert_eq!(plan.unsourced, vec![shard()]);
        let mut session = fx.session();
        session.advance_epoch(2).unwrap();
        fx.fence.advance(2);
        cover_plan(&mut session, 1, 3, &mut plan).unwrap();
        assert!(plan.checkpoint_free(), "stripes must cover the wiped group");
        assert_eq!(plan.reconstructions.len(), 1);
        assert_eq!(plan.reconstructions[0].targets, vec![1, 3]);
        let rebuilt =
            reconstruct_shard(&mut session, 1, &plan.reconstructions[0], 2, &fx.fence)
                .unwrap();
        assert_eq!(rebuilt.content_hash(), snap.content_hash());
    }

    #[test]
    fn pull_of_a_missing_stripe_is_a_clean_error() {
        let fence = EpochFence::new(1);
        let depot = StripeDepot::start(fence.clone(), 8 * 1024).unwrap();
        let err =
            pull_stripe(depot.addr(), stripe_tag(shard()), 0, 1, 1, &fence).unwrap_err();
        assert!(!err.retryable());
        assert!(err.to_string().contains("does not hold"), "{err}");
    }
}
