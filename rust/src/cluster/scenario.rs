//! Paper-scale recovery scenarios on the discrete-event simulator.
//!
//! `simulate_flash` and `simulate_vanilla` replay one failure +
//! recovery at cluster scales we cannot run for real (Tab. II and
//! Tab. III in the paper), using the calibrated [`LatencyModel`]. The
//! protocol *structure* mirrors the real coordinator: the same phases,
//! concurrency, and ordering — only the per-operation latencies are
//! drawn from distributions instead of measured.
//!
//! The fault being injected and the protocol phase costs are exposed as
//! standalone pieces ([`SimFault`], [`sample_detection_s`],
//! [`flash_restart_cost`], [`vanilla_restart_cost`]) so campaign-level
//! drivers — notably the chaos scenario engine (`crate::chaos`) — can
//! compose multi-failure timelines (cascades, flaps, failures striking
//! mid-recovery) out of the same calibrated protocol math instead of
//! re-deriving it.

use super::failure::{FailureCategory, FailureInjector, FailureKind};
use super::latency::{LatencyModel, StepTimeModel};
use super::node::{NodeState, SimCluster};
use super::simtime::Sim;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct ScenarioConfig {
    /// Devices in the job (paper sweeps 32 .. 4800 .. 18000).
    pub devices: usize,
    pub devices_per_node: usize,
    /// Model parameter count (7e9 / 70e9 / 175e9 in Tab. II/III).
    pub model_params: f64,
    pub lat: LatencyModel,
    pub step: StepTimeModel,
    pub heartbeat_interval_s: f64,
    pub miss_threshold: u32,
    /// Vanilla baseline collective hang timeout (paper: 1800 s).
    pub collective_timeout_s: f64,
    /// TCP-Store establishment parallelism (1 = serialized baseline).
    pub tcp_parallelism: usize,
    pub seed: u64,
}

impl ScenarioConfig {
    pub fn paper(devices: usize, model_params: f64, seed: u64) -> Self {
        ScenarioConfig {
            devices,
            devices_per_node: 8,
            model_params,
            lat: LatencyModel::default(),
            step: StepTimeModel::default(),
            heartbeat_interval_s: 2.0,
            miss_threshold: 3,
            collective_timeout_s: 1800.0,
            tcp_parallelism: 64,
            seed,
        }
    }

    pub fn nodes(&self) -> usize {
        self.devices.div_ceil(self.devices_per_node)
    }

    /// Communication neighbours per device (ring/tree collectives:
    /// grows with log of scale, not with scale).
    pub fn neighbors(&self) -> usize {
        (self.devices.max(2) as f64).log2().ceil() as usize + 2
    }

    /// Bytes of model state per device (params + grads + Adam m/v in
    /// mixed precision ~ 16 B/param, sharded over the model-parallel
    /// world of at most 128 devices).
    pub fn state_bytes_per_device(&self) -> f64 {
        16.0 * self.model_params / self.devices.min(128) as f64
    }
}

/// One fault to inject into a simulated scenario. `None` fields are
/// sampled from the run's RNG, reproducing the original single-shot
/// behaviour; campaign drivers pin them from a declarative spec.
#[derive(Debug, Clone, Copy, Default)]
pub struct SimFault {
    /// Victim node index (sampled uniformly when `None`).
    pub node: Option<usize>,
    /// Failure kind (sampled from the Fig. 9 mix when `None`).
    pub kind: Option<FailureKind>,
}

/// Failure occurrence -> controller aware, for `kind` under the
/// cluster's heartbeat configuration (paper §III-C).
pub fn sample_detection_s(
    cfg: &ScenarioConfig,
    kind: FailureKind,
    rng: &mut Rng,
) -> f64 {
    // Device plugin (hardware) reports within its poll period; software
    // failures surface via missed heartbeats.
    let notice = cfg.lat.detect_notice(rng);
    match kind.category() {
        FailureCategory::Hardware => notice + rng.range_f64(0.5, 1.5),
        FailureCategory::Software => {
            // Fault lands uniformly within a heartbeat period; the
            // controller fires after `miss_threshold` silent periods.
            let phase = rng.f64() * cfg.heartbeat_interval_s;
            notice + phase + cfg.miss_threshold as f64 * cfg.heartbeat_interval_s
        }
    }
}

/// Cost of one restart protocol run on the critical path, broken into
/// the stages Tab. II/III report.
#[derive(Debug, Clone)]
pub struct RestartCost {
    /// Controller aware -> all workers training again.
    pub critical_path_s: f64,
    /// Point on the critical path where the comm group is up (state
    /// restore still outstanding).
    pub comm_done_s: f64,
    /// Time the slower of (normal fleet, replacements) is ready.
    pub join_s: f64,
    /// Controller decision + strategy broadcast (start of the path).
    pub decide_s: f64,
    /// Normal fleet's stop/clean/reset time (max over nodes).
    pub normal_max_s: f64,
    pub stages: Vec<(String, f64)>,
}

/// FlashRecovery restart protocol (paper §III-D/E) with `replacements`
/// nodes recreated concurrently — the k=1 case is the paper's
/// experiment; campaign drivers pass k>1 for partitions and merged
/// recoveries.
pub fn flash_restart_cost(
    cfg: &ScenarioConfig,
    replacements: usize,
    rng: &mut Rng,
) -> RestartCost {
    let nodes = cfg.nodes();
    let replacements = replacements.max(1).min(nodes);

    // Controller decision fans out suspend + reschedule concurrently.
    let decide = cfg.lat.controller_decide_s;

    // (a) every normal node: stop kernels, clean task queue, reset
    // devices — in parallel; the fleet is ready at the max.
    let mut normal_max = 0.0f64;
    for _ in 0..nodes.saturating_sub(replacements) {
        normal_max = normal_max.max(rng.range_f64(1.0, 3.0));
    }

    // (b) each faulty node: decommission, substitute spare, start ONE
    // container (scale-independent — the paper's key point). With k
    // concurrent replacements each phase waits for its slowest member,
    // and the k containers contend on shared storage for the python
    // env. (k=1 reproduces the original single-draw behaviour.)
    let mut resched_max = 0.0f64;
    let mut cstart_max = 0.0f64;
    for _ in 0..replacements {
        resched_max = resched_max.max(cfg.lat.reschedule(rng));
        cstart_max = cstart_max.max(cfg.lat.container_start(rng));
    }
    let pyenv = cfg.lat.storage_load(replacements, 0.0);

    // (c) once both are ready: communication-group re-establishment.
    let torch_agent = cfg.lat.torch_agent_s;
    let tcp = cfg
        .lat
        .tcp_store_establishment(cfg.devices, cfg.tcp_parallelism);
    let ranktable = cfg.lat.ranktable_shared(cfg.devices);
    let links = cfg.neighbors() as f64 * cfg.lat.link_per_neighbor_s;
    let comm = torch_agent + tcp + ranktable + links;

    // (d) replica-based state restore: each replacement pulls its
    // node's shard from a surviving replica; transfers run in parallel
    // so the critical path is one node's worth of bytes.
    let restore = cfg
        .lat
        .replica_transfer(cfg.state_bytes_per_device() * cfg.devices_per_node as f64);

    let join = decide + normal_max.max(resched_max + cstart_max + pyenv);
    RestartCost {
        critical_path_s: join + comm + restore,
        comm_done_s: join + comm,
        join_s: join,
        decide_s: decide,
        normal_max_s: normal_max,
        stages: vec![
            ("controller_decide".to_string(), decide),
            ("normal_stop_clean_reset".to_string(), normal_max),
            ("reschedule_spare".to_string(), resched_max),
            ("container_start".to_string(), cstart_max + pyenv),
            ("torch_agent".to_string(), torch_agent),
            ("tcp_store".to_string(), tcp),
            ("ranktable_shared".to_string(), ranktable),
            ("device_links".to_string(), links),
            ("replica_restore".to_string(), restore),
        ],
    }
}

/// Vanilla restart protocol: indiscriminate full-fleet recreation,
/// serialized TCP-Store, original ranktable, checkpoint reload.
pub fn vanilla_restart_cost(cfg: &ScenarioConfig, rng: &mut Rng) -> RestartCost {
    let nodes = cfg.nodes();

    // Teardown of every container (parallel; max over fleet).
    let mut stop_max = 0.0f64;
    for _ in 0..nodes {
        stop_max = stop_max.max(cfg.lat.container_stop(rng));
    }

    // Node replacement happens concurrently with teardown.
    let resched = cfg.lat.reschedule(rng);

    // Restart of every container: fleet waits for the slowest start
    // (max order statistic of N(mean, std) clamped), plus shared-storage
    // contention as every container cold-loads the python environment.
    let mut start_max = 0.0f64;
    for _ in 0..nodes {
        start_max = start_max.max(cfg.lat.container_start(rng));
    }
    let pyenv = cfg.lat.storage_load(nodes, 0.0);

    // Communication group: serialized TCP-Store + original ranktable.
    let torch_agent = cfg.lat.torch_agent_s;
    let tcp = cfg.lat.tcp_store_establishment(cfg.devices, 1);
    let ranktable = cfg.lat.ranktable_original(cfg.devices);
    let links = cfg.neighbors() as f64 * cfg.lat.link_per_neighbor_s;

    // Checkpoint reload: every device re-reads its state shard from
    // shared storage; aggregate bytes grow with the DP replica count.
    let ckpt_total_bytes = cfg.state_bytes_per_device() * cfg.devices as f64;
    let ckpt = ckpt_total_bytes / cfg.lat.storage_agg_bw_bytes;

    let join = stop_max.max(resched) + start_max + pyenv;
    RestartCost {
        critical_path_s: join + torch_agent + tcp + ranktable + links + ckpt,
        comm_done_s: join + torch_agent + tcp + ranktable + links,
        join_s: join,
        decide_s: 0.0,
        normal_max_s: 0.0,
        stages: vec![
            ("container_stop".to_string(), stop_max),
            ("reschedule".to_string(), resched),
            ("container_start_fleet".to_string(), start_max),
            ("pyenv_storage_contention".to_string(), pyenv),
            ("torch_agent".to_string(), torch_agent),
            ("tcp_store_serial".to_string(), tcp),
            ("ranktable_original".to_string(), ranktable),
            ("device_links".to_string(), links),
            ("checkpoint_reload".to_string(), ckpt),
        ],
    }
}

/// One simulated recovery, broken down the way Tab. III reports it.
#[derive(Debug, Clone)]
pub struct RecoveryBreakdown {
    pub detection_s: f64,
    pub restart_s: f64,
    pub step_time_s: f64,
    /// Expected redone training = step/2 (§II assumption on `s1`).
    pub redone_s: f64,
    pub total_s: f64,
    /// Fine-grained (stage name, seconds) for the restart phase.
    pub stages: Vec<(String, f64)>,
}

/// World state threaded through the restart DES.
#[derive(Default)]
struct RestartWorld {
    cluster: Option<SimCluster>,
    normal_ready_at: f64,
    replacement_ready_at: f64,
    comm_done_at: f64,
    restore_done_at: f64,
}

/// FlashRecovery with the paper's hardcoded single sampled failure.
pub fn simulate_flash(cfg: &ScenarioConfig) -> RecoveryBreakdown {
    simulate_flash_with(cfg, SimFault::default())
}

/// FlashRecovery: heartbeat/plugin detection, selective recreation of
/// the faulty node only, parallel TCP-Store, shared-file ranktable,
/// replica-based state restore (paper §III, Tab. III). The injected
/// fault is a parameter so campaign drivers control victim and kind.
pub fn simulate_flash_with(cfg: &ScenarioConfig, fault: SimFault) -> RecoveryBreakdown {
    let mut rng = Rng::new(cfg.seed ^ 0xF1A5);
    let kind = fault
        .kind
        .unwrap_or_else(|| FailureInjector::sample_kind(&mut rng));

    let detection_s = sample_detection_s(cfg, kind, &mut rng);

    // ---- restart: DES over the concurrent per-node recovery protocol.
    let nodes = cfg.nodes();
    let mut world = RestartWorld {
        cluster: Some(SimCluster::new(nodes, 1, cfg.devices_per_node)),
        ..Default::default()
    };
    let mut sim: Sim<RestartWorld> = Sim::new();
    let faulty = fault
        .node
        .unwrap_or_else(|| rng.below(nodes as u64) as usize)
        .min(nodes - 1);

    let cost = flash_restart_cost(cfg, 1, &mut rng);
    let join = cost.join_s;
    let (comm_done, restore_done) = (cost.comm_done_s, cost.critical_path_s);

    // (a) every normal node is suspended once the fleet has stopped,
    // cleaned, and reset.
    let (decide, normal_max) = (cost.decide_s, cost.normal_max_s);
    sim.schedule(decide + normal_max, move |w: &mut RestartWorld, s| {
        w.normal_ready_at = s.now();
        let c = w.cluster.as_mut().unwrap();
        for id in 0..c.nodes.len() {
            if c.nodes[id].state == NodeState::Running && id != faulty {
                c.set_state(id, NodeState::Suspended);
            }
        }
    });

    // (b) faulty node: decommission, substitute spare, start ONE
    // container (scale-independent — this is the paper's key point).
    sim.schedule(join, move |w: &mut RestartWorld, s| {
        w.replacement_ready_at = s.now();
        let c = w.cluster.as_mut().unwrap();
        c.fail_node(faulty).unwrap();
        c.substitute(faulty).unwrap();
    });

    // (c) comm group + state restore at the join point; the DES
    // resolves the ordering.
    sim.at(comm_done, move |w: &mut RestartWorld, s| {
        w.comm_done_at = s.now();
    });
    sim.at(restore_done, move |w: &mut RestartWorld, s| {
        w.restore_done_at = s.now();
        let c = w.cluster.as_mut().unwrap();
        for id in 0..c.nodes.len() {
            if matches!(c.nodes[id].state, NodeState::Suspended | NodeState::Starting) {
                c.set_state(id, NodeState::Running);
            }
        }
    });

    sim.run(&mut world);
    let restart_s = world.restore_done_at;
    debug_assert!(world.comm_done_at <= restart_s);
    debug_assert_eq!(
        world.cluster.as_ref().unwrap().count(NodeState::Running),
        nodes
    );

    let step_time_s = cfg.step.step_time_s(cfg.model_params, cfg.devices);
    let redone_s = step_time_s / 2.0;
    let mut bd_stages = cost.stages;
    bd_stages.push(("redone_half_step".to_string(), redone_s));

    RecoveryBreakdown {
        detection_s,
        restart_s,
        step_time_s,
        redone_s,
        total_s: detection_s + restart_s + redone_s,
        stages: bd_stages,
    }
}

/// Vanilla baseline: collective-timeout detection, indiscriminate
/// full-fleet container recreation, serialized TCP-Store, original
/// ranktable negotiation, checkpoint reload (paper §II, Tab. II).
pub fn simulate_vanilla(cfg: &ScenarioConfig) -> RecoveryBreakdown {
    let mut rng = Rng::new(cfg.seed ^ 0x7A21_11A);

    // Detection: the hang is only noticed when the collective times out.
    let detection_s = cfg.collective_timeout_s;

    let cost = vanilla_restart_cost(cfg, &mut rng);
    let step_time_s = cfg.step.step_time_s(cfg.model_params, cfg.devices);
    // Recomputation from the checkpoint is t/2 steps (excluded from the
    // paper's Tab. II, reported separately via the §II overhead model).
    let redone_s = 0.0;

    RecoveryBreakdown {
        detection_s,
        restart_s: cost.critical_path_s,
        step_time_s,
        redone_s,
        total_s: detection_s + cost.critical_path_s,
        stages: cost.stages,
    }
}

/// Average breakdown over `runs` seeds (Monte-Carlo smoothing).
pub fn average<F>(runs: u64, base_seed: u64, f: F) -> RecoveryBreakdown
where
    F: Fn(u64) -> RecoveryBreakdown,
{
    assert!(runs > 0);
    let mut acc: Option<RecoveryBreakdown> = None;
    for i in 0..runs {
        let b = f(base_seed + i);
        acc = Some(match acc {
            None => b,
            Some(mut a) => {
                a.detection_s += b.detection_s;
                a.restart_s += b.restart_s;
                a.step_time_s += b.step_time_s;
                a.redone_s += b.redone_s;
                a.total_s += b.total_s;
                for (i, (_, v)) in b.stages.iter().enumerate() {
                    if let Some(s) = a.stages.get_mut(i) {
                        s.1 += v;
                    }
                }
                a
            }
        });
    }
    let mut a = acc.unwrap();
    let n = runs as f64;
    a.detection_s /= n;
    a.restart_s /= n;
    a.step_time_s /= n;
    a.redone_s /= n;
    a.total_s /= n;
    for s in &mut a.stages {
        s.1 /= n;
    }
    a
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flash_avg(devices: usize, params: f64) -> RecoveryBreakdown {
        average(16, 1, |s| {
            simulate_flash(&ScenarioConfig::paper(devices, params, s))
        })
    }

    fn vanilla_avg(devices: usize, params: f64) -> RecoveryBreakdown {
        average(16, 1, |s| {
            simulate_vanilla(&ScenarioConfig::paper(devices, params, s))
        })
    }

    #[test]
    fn flash_detection_within_seconds() {
        let b = flash_avg(960, 7e9);
        assert!(b.detection_s > 1.0 && b.detection_s < 15.0, "{}", b.detection_s);
    }

    #[test]
    fn flash_restart_nearly_scale_independent() {
        // Paper Tab. III: 32 -> 4800 devices raises total by ~52%.
        let small = flash_avg(32, 7e9);
        let large = flash_avg(4800, 175e9);
        assert!(
            large.restart_s / small.restart_s < 1.6,
            "restart grew {}x ({} -> {})",
            large.restart_s / small.restart_s,
            small.restart_s,
            large.restart_s
        );
    }

    #[test]
    fn flash_total_matches_paper_magnitude() {
        // Paper: 97-150 s across the whole sweep.
        for (dev, p) in [(32, 7e9), (960, 7e9), (2880, 70e9), (4800, 175e9)] {
            let b = flash_avg(dev, p);
            assert!(
                b.total_s > 50.0 && b.total_s < 250.0,
                "{dev} devices: total {}",
                b.total_s
            );
        }
    }

    #[test]
    fn vanilla_restart_grows_linearly() {
        let a = vanilla_avg(1824, 175e9);
        let b = vanilla_avg(3936, 175e9);
        let c = vanilla_avg(5472, 175e9);
        assert!(b.restart_s > a.restart_s * 1.5, "{} vs {}", a.restart_s, b.restart_s);
        assert!(c.restart_s > b.restart_s * 1.2, "{} vs {}", b.restart_s, c.restart_s);
        // paper magnitudes: 231 / 801 / 1115 s — within ~2x
        assert!(a.restart_s > 100.0 && a.restart_s < 500.0, "{}", a.restart_s);
        assert!(c.restart_s > 550.0 && c.restart_s < 2300.0, "{}", c.restart_s);
    }

    #[test]
    fn vanilla_detection_is_the_timeout() {
        let b = vanilla_avg(1824, 175e9);
        assert_eq!(b.detection_s, 1800.0);
    }

    #[test]
    fn flash_beats_vanilla_everywhere() {
        for (dev, p) in [(960, 7e9), (2880, 70e9), (4800, 175e9)] {
            let f = flash_avg(dev, p);
            let v = vanilla_avg(dev, p);
            assert!(
                f.total_s < v.total_s / 5.0,
                "{dev}: flash {} vs vanilla {}",
                f.total_s,
                v.total_s
            );
        }
    }

    #[test]
    fn breakdown_stages_sum_close_to_restart() {
        let cfg = ScenarioConfig::paper(960, 70e9, 3);
        let b = simulate_flash(&cfg);
        let sum: f64 = b
            .stages
            .iter()
            .filter(|(n, _)| n != "redone_half_step")
            .map(|(_, v)| v)
            .sum();
        // Stages overlap (normal fleet vs replacement are concurrent) so
        // the serial sum must be >= the critical-path restart time.
        assert!(sum >= b.restart_s - 1e-9, "sum {sum} restart {}", b.restart_s);
    }

    #[test]
    fn deterministic_given_seed() {
        let cfg = ScenarioConfig::paper(960, 7e9, 9);
        let a = simulate_flash(&cfg);
        let b = simulate_flash(&cfg);
        assert_eq!(a.total_s, b.total_s);
    }

    #[test]
    fn injected_fault_pins_victim_and_kind() {
        let cfg = ScenarioConfig::paper(960, 7e9, 11);
        let f = SimFault { node: Some(3), kind: Some(FailureKind::Network) };
        let a = simulate_flash_with(&cfg, f);
        let b = simulate_flash_with(&cfg, f);
        assert_eq!(a.total_s, b.total_s);
        // hardware detection path: bounded by notice + report, no
        // heartbeat-miss wait
        assert!(a.detection_s < 6.0, "{}", a.detection_s);
    }

    #[test]
    fn multi_replacement_restart_costs_more_but_sublinearly() {
        let cfg = ScenarioConfig::paper(960, 7e9, 5);
        let one = average(32, 1, |s| {
            let mut rng = Rng::new(s);
            let c = flash_restart_cost(&ScenarioConfig { seed: s, ..cfg.clone() }, 1, &mut rng);
            RecoveryBreakdown {
                detection_s: 0.0,
                restart_s: c.critical_path_s,
                step_time_s: 0.0,
                redone_s: 0.0,
                total_s: c.critical_path_s,
                stages: c.stages,
            }
        });
        let four = average(32, 1, |s| {
            let mut rng = Rng::new(s);
            let c = flash_restart_cost(&ScenarioConfig { seed: s, ..cfg.clone() }, 4, &mut rng);
            RecoveryBreakdown {
                detection_s: 0.0,
                restart_s: c.critical_path_s,
                step_time_s: 0.0,
                redone_s: 0.0,
                total_s: c.critical_path_s,
                stages: c.stages,
            }
        });
        // waiting on the slowest of 4 containers costs more than 1 …
        assert!(four.restart_s > one.restart_s, "{} vs {}", one.restart_s, four.restart_s);
        // … but nowhere near 4x (recreation is parallel).
        assert!(four.restart_s < one.restart_s * 2.0);
    }
}
