//! Cluster substrate: discrete-event simulation engine, failure
//! taxonomy/injection (Fig. 9), calibrated latency model (DESIGN.md §6),
//! node inventory, and the paper-scale recovery scenarios behind
//! Tables II and III.

pub mod failure;
pub mod latency;
pub mod node;
pub mod scenario;
pub mod simtime;

pub use failure::{FailureCategory, FailureEvent, FailureInjector, FailureKind};
pub use latency::{LatencyModel, StepTimeModel};
pub use node::{NodeState, SimCluster, SimNode};
pub use scenario::{simulate_flash, simulate_vanilla, RecoveryBreakdown, ScenarioConfig};
pub use simtime::Sim;
