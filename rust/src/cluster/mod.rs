//! Cluster substrate: discrete-event simulation engine, failure
//! taxonomy/injection (Fig. 9), calibrated latency model (DESIGN.md §6),
//! node inventory, and the paper-scale recovery scenarios behind
//! Tables II and III.

pub mod failure;
pub mod latency;
pub mod node;
pub mod scenario;
pub mod simtime;

pub use failure::{FailureCategory, FailureEvent, FailureInjector, FailureKind};
pub use latency::{LatencyModel, StepTimeModel, WireMeasurements};
pub use node::{NodeState, SimCluster, SimNode};
pub use scenario::{
    flash_restart_cost, sample_detection_s, simulate_flash, simulate_flash_with,
    simulate_vanilla, vanilla_restart_cost, RecoveryBreakdown, RestartCost,
    ScenarioConfig, SimFault,
};
pub use simtime::Sim;
