//! Failure taxonomy and injection — paper Fig. 9.
//!
//! The paper reports hardware failures at 59.6% (network 57%, device
//! memory 20%, unclassified 11%, AICore / timeout / driver the rest)
//! and software failures at 40.4% (segfault 34%, resource errors,
//! torch-init, configuration, OOM, 9% unclassified). The injector
//! reproduces exactly this mix; `benches/fig9_failure_taxonomy.rs`
//! regenerates the figure from injector output.

use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FailureCategory {
    Hardware,
    Software,
}

/// Leaf failure types from Fig. 9.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum FailureKind {
    // -- hardware
    Network,
    DeviceMemory,
    AiCore,
    Timeout,
    Driver,
    HardwareOther,
    // -- software
    Segfault,
    ResourceError,
    TorchInit,
    ConfigAnomaly,
    Oom,
    SoftwareOther,
}

/// Share of hardware failures among all failures (paper: 59.6%).
pub const HARDWARE_SHARE: f64 = 0.596;

/// (kind, share-within-category) — hardware sums to 1.0.
pub const HARDWARE_MIX: [(FailureKind, f64); 6] = [
    (FailureKind::Network, 0.57),
    (FailureKind::DeviceMemory, 0.20),
    (FailureKind::HardwareOther, 0.11),
    (FailureKind::AiCore, 0.05),
    (FailureKind::Timeout, 0.04),
    (FailureKind::Driver, 0.03),
];

/// (kind, share-within-category) — software sums to 1.0.
pub const SOFTWARE_MIX: [(FailureKind, f64); 6] = [
    (FailureKind::Segfault, 0.34),
    (FailureKind::ResourceError, 0.20),
    (FailureKind::TorchInit, 0.15),
    (FailureKind::ConfigAnomaly, 0.12),
    (FailureKind::Oom, 0.10),
    (FailureKind::SoftwareOther, 0.09),
];

impl FailureKind {
    pub fn category(&self) -> FailureCategory {
        use FailureKind::*;
        match self {
            Network | DeviceMemory | AiCore | Timeout | Driver | HardwareOther => {
                FailureCategory::Hardware
            }
            _ => FailureCategory::Software,
        }
    }

    pub fn name(&self) -> &'static str {
        use FailureKind::*;
        match self {
            Network => "network",
            DeviceMemory => "device_memory",
            AiCore => "aicore",
            Timeout => "timeout",
            Driver => "driver",
            HardwareOther => "hardware_other",
            Segfault => "segfault",
            ResourceError => "resource_error",
            TorchInit => "torch_init",
            ConfigAnomaly => "config_anomaly",
            Oom => "oom",
            SoftwareOther => "software_other",
        }
    }

    /// Inverse of [`FailureKind::name`] (chaos spec parsing).
    pub fn from_name(name: &str) -> Option<FailureKind> {
        Self::all().into_iter().find(|k| k.name() == name)
    }

    pub fn all() -> Vec<FailureKind> {
        HARDWARE_MIX
            .iter()
            .chain(SOFTWARE_MIX.iter())
            .map(|(k, _)| *k)
            .collect()
    }

    /// Overall probability of this kind among all failures.
    pub fn overall_share(&self) -> f64 {
        let (mix, cat_share): (&[(FailureKind, f64)], f64) =
            match self.category() {
                FailureCategory::Hardware => (&HARDWARE_MIX, HARDWARE_SHARE),
                FailureCategory::Software => (&SOFTWARE_MIX, 1.0 - HARDWARE_SHARE),
            };
        mix.iter()
            .find(|(k, _)| k == self)
            .map(|(_, w)| w * cat_share)
            .unwrap_or(0.0)
    }
}

/// Whether a failure is detectable by the device plugin (hardware
/// signals) or only by the monitoring process (process death). Both
/// paths feed the controller; this only affects which component
/// reports first in the real engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DetectionPath {
    DevicePlugin,
    MonitorProcess,
}

impl FailureKind {
    pub fn detection_path(&self) -> DetectionPath {
        match self.category() {
            FailureCategory::Hardware => DetectionPath::DevicePlugin,
            FailureCategory::Software => DetectionPath::MonitorProcess,
        }
    }
}

/// A concrete injected failure.
#[derive(Debug, Clone)]
pub struct FailureEvent {
    /// Seconds from injector start.
    pub at: f64,
    /// Victim node index.
    pub node: usize,
    pub kind: FailureKind,
}

/// Samples failure arrivals (Poisson process over the cluster) and
/// victims/kinds per Fig. 9.
pub struct FailureInjector {
    rng: Rng,
    cluster_mtbf_s: f64,
    num_nodes: usize,
    clock: f64,
}

impl FailureInjector {
    pub fn new(num_nodes: usize, cluster_mtbf_s: f64, seed: u64) -> Self {
        assert!(num_nodes > 0);
        assert!(cluster_mtbf_s > 0.0);
        FailureInjector {
            rng: Rng::new(seed ^ 0xFA11_u64),
            cluster_mtbf_s,
            num_nodes,
            clock: 0.0,
        }
    }

    /// Sample a kind from the Fig. 9 distribution.
    pub fn sample_kind(rng: &mut Rng) -> FailureKind {
        let (mix, _) = if rng.bool(HARDWARE_SHARE) {
            (&HARDWARE_MIX, FailureCategory::Hardware)
        } else {
            (&SOFTWARE_MIX, FailureCategory::Software)
        };
        let weights: Vec<f64> = mix.iter().map(|(_, w)| *w).collect();
        mix[rng.weighted(&weights)].0
    }

    /// Next failure event (advances the internal clock).
    pub fn next(&mut self) -> FailureEvent {
        self.clock += self.rng.exponential(1.0 / self.cluster_mtbf_s);
        FailureEvent {
            at: self.clock,
            node: self.rng.below(self.num_nodes as u64) as usize,
            kind: Self::sample_kind(&mut self.rng),
        }
    }

    /// All failures within a horizon (seconds).
    pub fn within(&mut self, horizon_s: f64) -> Vec<FailureEvent> {
        let mut out = Vec::new();
        loop {
            let e = self.next();
            if e.at > horizon_s {
                // Put the clock back so `within` can be called again.
                self.clock = horizon_s;
                break;
            }
            out.push(e);
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mixes_sum_to_one() {
        let hw: f64 = HARDWARE_MIX.iter().map(|(_, w)| w).sum();
        let sw: f64 = SOFTWARE_MIX.iter().map(|(_, w)| w).sum();
        assert!((hw - 1.0).abs() < 1e-9);
        assert!((sw - 1.0).abs() < 1e-9);
    }

    #[test]
    fn overall_shares_sum_to_one() {
        let total: f64 = FailureKind::all().iter().map(|k| k.overall_share()).sum();
        assert!((total - 1.0).abs() < 1e-9);
    }

    #[test]
    fn categories_are_consistent() {
        for (k, _) in HARDWARE_MIX {
            assert_eq!(k.category(), FailureCategory::Hardware);
        }
        for (k, _) in SOFTWARE_MIX {
            assert_eq!(k.category(), FailureCategory::Software);
        }
    }

    #[test]
    fn sampled_mix_converges_to_fig9() {
        let mut rng = Rng::new(0);
        let n = 200_000;
        let mut hardware = 0u32;
        let mut network = 0u32;
        let mut segfault = 0u32;
        for _ in 0..n {
            let k = FailureInjector::sample_kind(&mut rng);
            if k.category() == FailureCategory::Hardware {
                hardware += 1;
            }
            if k == FailureKind::Network {
                network += 1;
            }
            if k == FailureKind::Segfault {
                segfault += 1;
            }
        }
        let hw_frac = hardware as f64 / n as f64;
        assert!((hw_frac - HARDWARE_SHARE).abs() < 0.01, "hw={hw_frac}");
        let net_frac = network as f64 / n as f64;
        assert!((net_frac - 0.596 * 0.57).abs() < 0.01, "net={net_frac}");
        let seg_frac = segfault as f64 / n as f64;
        assert!((seg_frac - 0.404 * 0.34).abs() < 0.01, "seg={seg_frac}");
    }

    #[test]
    fn arrivals_match_mtbf() {
        let mut inj = FailureInjector::new(100, 1000.0, 7);
        let events = inj.within(1_000_000.0);
        // Poisson with rate 1/1000: expect ~1000 events over 1e6 s.
        assert!((events.len() as f64 - 1000.0).abs() < 120.0, "{}", events.len());
        // strictly increasing times, nodes in range
        for w in events.windows(2) {
            assert!(w[1].at > w[0].at);
        }
        assert!(events.iter().all(|e| e.node < 100));
    }

    #[test]
    fn injector_is_deterministic() {
        let a: Vec<_> = (0..10).map(|_| FailureInjector::new(8, 100.0, 42).next().node).collect();
        let b: Vec<_> = (0..10).map(|_| FailureInjector::new(8, 100.0, 42).next().node).collect();
        assert_eq!(a, b);
    }

    #[test]
    fn detection_paths() {
        assert_eq!(FailureKind::Network.detection_path(), DetectionPath::DevicePlugin);
        assert_eq!(FailureKind::Segfault.detection_path(), DetectionPath::MonitorProcess);
    }
}
