//! Discrete-event simulation engine.
//!
//! The paper's experiments run on a >10,000-NPU cluster; this engine
//! lets us replay the *same protocols* (restart phases, heartbeats,
//! checkpoint I/O) at that scale on one machine, with latencies drawn
//! from distributions calibrated to the paper's reported numbers
//! (DESIGN.md §6).
//!
//! `Sim<W>` is generic over a world type `W`. Events are closures
//! scheduled at absolute sim-times; ties break by insertion order so
//! runs are fully deterministic.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

type EventFn<W> = Box<dyn FnOnce(&mut W, &mut Sim<W>)>;

struct Entry<W> {
    at: f64,
    seq: u64,
    run: EventFn<W>,
}

impl<W> PartialEq for Entry<W> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<W> Eq for Entry<W> {}
impl<W> PartialOrd for Entry<W> {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl<W> Ord for Entry<W> {
    fn cmp(&self, other: &Self) -> Ordering {
        // BinaryHeap is a max-heap: reverse so earliest time pops first.
        other
            .at
            .partial_cmp(&self.at)
            .unwrap_or(Ordering::Equal)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

pub struct Sim<W> {
    now: f64,
    seq: u64,
    queue: BinaryHeap<Entry<W>>,
    processed: u64,
}

impl<W> Default for Sim<W> {
    fn default() -> Self {
        Self::new()
    }
}

impl<W> Sim<W> {
    pub fn new() -> Self {
        Sim { now: 0.0, seq: 0, queue: BinaryHeap::new(), processed: 0 }
    }

    /// Current simulation time (seconds).
    pub fn now(&self) -> f64 {
        self.now
    }

    /// Total events processed so far.
    pub fn processed(&self) -> u64 {
        self.processed
    }

    /// Schedule `f` to run `delay` seconds from now.
    pub fn schedule<F>(&mut self, delay: f64, f: F)
    where
        F: FnOnce(&mut W, &mut Sim<W>) + 'static,
    {
        assert!(delay >= 0.0, "negative delay {delay}");
        self.at(self.now + delay, f);
    }

    /// Schedule `f` at absolute time `at` (must be >= now).
    pub fn at<F>(&mut self, at: f64, f: F)
    where
        F: FnOnce(&mut W, &mut Sim<W>) + 'static,
    {
        assert!(at >= self.now, "scheduling into the past: {at} < {}", self.now);
        self.seq += 1;
        self.queue.push(Entry { at, seq: self.seq, run: Box::new(f) });
    }

    /// Run until the queue drains. Returns the final time.
    pub fn run(&mut self, world: &mut W) -> f64 {
        while let Some(e) = self.queue.pop() {
            self.now = e.at;
            self.processed += 1;
            (e.run)(world, self);
        }
        self.now
    }

    /// Run until the queue drains or sim-time exceeds `deadline`.
    pub fn run_until(&mut self, world: &mut W, deadline: f64) -> f64 {
        while let Some(top) = self.queue.peek() {
            if top.at > deadline {
                self.now = deadline;
                return self.now;
            }
            let e = self.queue.pop().unwrap();
            self.now = e.at;
            self.processed += 1;
            (e.run)(world, self);
        }
        self.now
    }

    pub fn is_idle(&self) -> bool {
        self.queue.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_run_in_time_order() {
        let mut sim: Sim<Vec<u32>> = Sim::new();
        let mut world = Vec::new();
        sim.schedule(3.0, |w: &mut Vec<u32>, _| w.push(3));
        sim.schedule(1.0, |w: &mut Vec<u32>, _| w.push(1));
        sim.schedule(2.0, |w: &mut Vec<u32>, _| w.push(2));
        let end = sim.run(&mut world);
        assert_eq!(world, vec![1, 2, 3]);
        assert_eq!(end, 3.0);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut sim: Sim<Vec<u32>> = Sim::new();
        let mut world = Vec::new();
        for i in 0..5 {
            sim.schedule(1.0, move |w: &mut Vec<u32>, _| w.push(i));
        }
        sim.run(&mut world);
        assert_eq!(world, vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn events_can_schedule_events() {
        let mut sim: Sim<Vec<f64>> = Sim::new();
        let mut world = Vec::new();
        sim.schedule(1.0, |_, s: &mut Sim<Vec<f64>>| {
            s.schedule(1.5, |w: &mut Vec<f64>, s| w.push(s.now()));
        });
        sim.run(&mut world);
        assert_eq!(world, vec![2.5]);
    }

    #[test]
    fn run_until_stops_at_deadline() {
        let mut sim: Sim<u32> = Sim::new();
        let mut world = 0u32;
        sim.schedule(1.0, |w: &mut u32, _| *w += 1);
        sim.schedule(10.0, |w: &mut u32, _| *w += 100);
        let t = sim.run_until(&mut world, 5.0);
        assert_eq!(world, 1);
        assert_eq!(t, 5.0);
        assert!(!sim.is_idle());
        sim.run(&mut world);
        assert_eq!(world, 101);
    }

    #[test]
    #[should_panic(expected = "negative delay")]
    fn rejects_negative_delay() {
        let mut sim: Sim<()> = Sim::new();
        sim.schedule(-1.0, |_, _| {});
    }

    #[test]
    fn processed_counts_events() {
        let mut sim: Sim<()> = Sim::new();
        for _ in 0..7 {
            sim.schedule(0.5, |_, _| {});
        }
        sim.run(&mut ());
        assert_eq!(sim.processed(), 7);
    }
}
