//! Simulated cluster inventory: nodes, device slots, container state,
//! and the spare-node pool the scheduler substitutes from.

use anyhow::{bail, Result};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeState {
    /// Healthy and participating in the job.
    Running,
    /// Healthy, training suspended, awaiting continue signal.
    Suspended,
    /// Declared failed by the controller.
    Faulty,
    /// Healthy standby, not in the job.
    Spare,
    /// Replacement node bringing its container up.
    Starting,
}

#[derive(Debug, Clone)]
pub struct SimNode {
    pub id: usize,
    pub state: NodeState,
    /// Devices hosted by this node (global device ids).
    pub devices: Vec<usize>,
}

/// Cluster inventory for the simulated control plane.
#[derive(Debug, Clone)]
pub struct SimCluster {
    pub nodes: Vec<SimNode>,
    pub devices_per_node: usize,
}

impl SimCluster {
    /// `active` nodes running the job + `spares` standby nodes.
    pub fn new(active: usize, spares: usize, devices_per_node: usize) -> Self {
        assert!(devices_per_node > 0);
        let mut nodes = Vec::with_capacity(active + spares);
        for id in 0..active {
            nodes.push(SimNode {
                id,
                state: NodeState::Running,
                devices: (id * devices_per_node..(id + 1) * devices_per_node)
                    .collect(),
            });
        }
        for id in active..active + spares {
            nodes.push(SimNode { id, state: NodeState::Spare, devices: vec![] });
        }
        SimCluster { nodes, devices_per_node }
    }

    pub fn active_devices(&self) -> usize {
        self.nodes
            .iter()
            .filter(|n| {
                matches!(n.state, NodeState::Running | NodeState::Suspended)
            })
            .map(|n| n.devices.len())
            .sum()
    }

    pub fn node_of_device(&self, device: usize) -> Option<usize> {
        self.nodes
            .iter()
            .find(|n| n.devices.contains(&device))
            .map(|n| n.id)
    }

    /// Mark `node` faulty; returns its device list.
    pub fn fail_node(&mut self, node: usize) -> Result<Vec<usize>> {
        let n = self
            .nodes
            .get_mut(node)
            .ok_or_else(|| anyhow::anyhow!("no node {node}"))?;
        if n.state == NodeState::Spare {
            bail!("spare node {node} cannot fail in-job");
        }
        n.state = NodeState::Faulty;
        Ok(n.devices.clone())
    }

    /// Substitute `faulty` with a spare: the spare adopts the faulty
    /// node's device ids (so the logical topology is unchanged — the
    /// essence of FlashRecovery's limited recreation). Returns the
    /// spare's node id.
    pub fn substitute(&mut self, faulty: usize) -> Result<usize> {
        if self.nodes[faulty].state != NodeState::Faulty {
            bail!("node {faulty} is not faulty");
        }
        let spare = self
            .nodes
            .iter()
            .position(|n| n.state == NodeState::Spare)
            .ok_or_else(|| anyhow::anyhow!("spare pool exhausted"))?;
        let devices = std::mem::take(&mut self.nodes[faulty].devices);
        self.nodes[spare].devices = devices;
        self.nodes[spare].state = NodeState::Starting;
        Ok(spare)
    }

    pub fn set_state(&mut self, node: usize, state: NodeState) {
        self.nodes[node].state = state;
    }

    pub fn count(&self, state: NodeState) -> usize {
        self.nodes.iter().filter(|n| n.state == state).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn layout_assigns_devices_contiguously() {
        let c = SimCluster::new(4, 1, 8);
        assert_eq!(c.nodes.len(), 5);
        assert_eq!(c.active_devices(), 32);
        assert_eq!(c.nodes[2].devices, (16..24).collect::<Vec<_>>());
        assert_eq!(c.node_of_device(17), Some(2));
        assert_eq!(c.count(NodeState::Spare), 1);
    }

    #[test]
    fn fail_and_substitute_preserves_device_ids() {
        let mut c = SimCluster::new(3, 2, 4);
        let lost = c.fail_node(1).unwrap();
        assert_eq!(lost, vec![4, 5, 6, 7]);
        let spare = c.substitute(1).unwrap();
        assert_eq!(spare, 3);
        assert_eq!(c.nodes[spare].devices, vec![4, 5, 6, 7]);
        assert_eq!(c.nodes[spare].state, NodeState::Starting);
        assert!(c.nodes[1].devices.is_empty());
    }

    #[test]
    fn substitute_requires_faulty_node() {
        let mut c = SimCluster::new(2, 1, 1);
        assert!(c.substitute(0).is_err());
    }

    #[test]
    fn spare_pool_exhaustion_errors() {
        let mut c = SimCluster::new(2, 1, 1);
        c.fail_node(0).unwrap();
        c.substitute(0).unwrap();
        c.fail_node(1).unwrap();
        assert!(c.substitute(1).is_err());
    }

    #[test]
    fn spare_cannot_fail() {
        let mut c = SimCluster::new(1, 1, 1);
        assert!(c.fail_node(1).is_err());
    }
}
