//! Calibrated latency model for the simulated control plane.
//!
//! Constants are fit to the paper's own reported measurements (Tab. I,
//! Tab. II, Tab. III, Fig. 10) so the simulator reproduces the *shape*
//! of every curve: what grows linearly with cluster size, what stays
//! constant, and roughly where the absolute numbers sit. DESIGN.md §6
//! records the calibration arithmetic.

use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct LatencyModel {
    // -- container lifecycle (§III-D factor 1)
    /// Container start ~ N(mean, std), clamped to [min, max]. Full-fleet
    /// restarts pay the max order statistic, hence the linear-ish tail
    /// growth the paper attributes to "normal distribution" startup.
    pub container_start_mean_s: f64,
    pub container_start_std_s: f64,
    pub container_start_min_s: f64,
    pub container_start_max_s: f64,
    /// Container teardown (uniform range).
    pub container_stop_min_s: f64,
    pub container_stop_max_s: f64,

    // -- node replacement
    /// Decommission faulty node + schedule spare (uniform range).
    pub reschedule_min_s: f64,
    pub reschedule_max_s: f64,

    // -- communication-group establishment (§III-D factor 2)
    /// Torch-agent establishment: fixed cost per restart.
    pub torch_agent_s: f64,
    /// Serial TCP-Store connection cost per device.
    pub tcp_store_per_link_s: f64,
    /// Fixed TCP-Store server bring-up.
    pub tcp_store_setup_s: f64,
    /// Original ranktable negotiation: linear + mild quadratic terms
    /// (fit to Tab. I's 8/31/60/176/249 s at 1k..18k devices).
    pub ranktable_linear_s_per_dev: f64,
    pub ranktable_quad_s_per_dev2: f64,
    /// Shared-file ranktable: fixed load + tiny size-dependent term.
    pub ranktable_shared_base_s: f64,
    pub ranktable_shared_per_dev_s: f64,
    /// Inter-device link establishment: per communication *neighbour*
    /// (scale-independent; depends on collective topology degree).
    pub link_per_neighbor_s: f64,

    // -- storage (§III-D factor 3)
    /// Aggregate shared-storage read bandwidth (bytes/s) for checkpoint
    /// + python-env loads; concurrent readers share it.
    pub storage_agg_bw_bytes: f64,
    /// Python environment bytes loaded per container on cold start.
    pub pyenv_bytes_per_container: f64,

    // -- training state restore (FlashRecovery §III-E)
    /// Device-to-device bandwidth for replica broadcast (bytes/s).
    pub d2d_bw_bytes: f64,

    // -- detection
    /// Extra latency from fault occurrence to plugin/monitor noticing.
    pub detect_notice_min_s: f64,
    pub detect_notice_max_s: f64,
    /// Controller decision + broadcast of recovery strategy.
    pub controller_decide_s: f64,
}

impl Default for LatencyModel {
    fn default() -> Self {
        LatencyModel {
            container_start_mean_s: 40.0,
            container_start_std_s: 8.0,
            container_start_min_s: 20.0,
            container_start_max_s: 90.0,
            container_stop_min_s: 2.0,
            container_stop_max_s: 6.0,
            reschedule_min_s: 25.0,
            reschedule_max_s: 45.0,
            torch_agent_s: 5.0,
            tcp_store_per_link_s: 0.018,
            tcp_store_setup_s: 0.5,
            ranktable_linear_s_per_dev: 0.0055,
            ranktable_quad_s_per_dev2: 4.0e-7,
            ranktable_shared_base_s: 0.1,
            ranktable_shared_per_dev_s: 2.0e-5,
            link_per_neighbor_s: 0.4,
            storage_agg_bw_bytes: 150.0e9,
            pyenv_bytes_per_container: 3.0e9,
            d2d_bw_bytes: 25.0e9,
            detect_notice_min_s: 1.0,
            detect_notice_max_s: 4.0,
            controller_decide_s: 1.0,
        }
    }
}

/// Wire numbers measured on the *live* plane by the impaired chaos
/// drivers (`chaos::live::drive_netem_*`, DESIGN.md §15) — the §6
/// re-calibration inputs that replace the paper-fit constants with
/// values this machine's sockets actually produced.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct WireMeasurements {
    /// Mean store-op round-trip over the measured link (s) — replaces
    /// `tcp_store_per_link_s`.
    pub tcp_store_per_link_s: f64,
    /// Measured last-good-heartbeat -> detection latency (s) —
    /// re-centers `detect_notice_min_s/max_s` around the wire number.
    pub detect_notice_s: f64,
}

impl LatencyModel {
    /// A model whose TCP-store and detection-notice constants are
    /// replaced by live wire measurements. The defaults stay the
    /// paper-fit values (pinned by tests); this is the §6 refresh
    /// path: `flashrecovery netem <scenario> --calibrate` measures,
    /// then simulator campaigns run on the refreshed model.
    pub fn with_wire(m: WireMeasurements) -> Self {
        let mut model = LatencyModel::default();
        if m.tcp_store_per_link_s > 0.0 && m.tcp_store_per_link_s.is_finite() {
            model.tcp_store_per_link_s = m.tcp_store_per_link_s;
        }
        if m.detect_notice_s > 0.0 && m.detect_notice_s.is_finite() {
            // keep the band shape (min..max spread) centered on the
            // measured notice latency
            model.detect_notice_min_s = m.detect_notice_s * 0.5;
            model.detect_notice_max_s = m.detect_notice_s * 1.5;
        }
        model
    }

    pub fn container_start(&self, rng: &mut Rng) -> f64 {
        rng.normal_clamped(
            self.container_start_mean_s,
            self.container_start_std_s,
            self.container_start_min_s,
            self.container_start_max_s,
        )
    }

    pub fn container_stop(&self, rng: &mut Rng) -> f64 {
        rng.range_f64(self.container_stop_min_s, self.container_stop_max_s)
    }

    pub fn reschedule(&self, rng: &mut Rng) -> f64 {
        rng.range_f64(self.reschedule_min_s, self.reschedule_max_s)
    }

    pub fn detect_notice(&self, rng: &mut Rng) -> f64 {
        rng.range_f64(self.detect_notice_min_s, self.detect_notice_max_s)
    }

    /// TCP-Store establishment for `n` devices with parallelism `p`
    /// (p=1 reproduces the serialized baseline, Fig. 10's green line).
    pub fn tcp_store_establishment(&self, n: usize, p: usize) -> f64 {
        let p = p.max(1) as f64;
        self.tcp_store_setup_s + (n as f64 / p).ceil() * self.tcp_store_per_link_s
    }

    /// Original (collect + distribute via master) ranktable update, O(n).
    pub fn ranktable_original(&self, n: usize) -> f64 {
        let n = n as f64;
        self.ranktable_linear_s_per_dev * n + self.ranktable_quad_s_per_dev2 * n * n
    }

    /// Shared-file ranktable load, O(1) in cluster size.
    pub fn ranktable_shared(&self, n: usize) -> f64 {
        self.ranktable_shared_base_s + self.ranktable_shared_per_dev_s * n as f64
    }

    /// Time for `readers` containers to cold-load the python env +
    /// `ckpt_bytes_per_reader` of checkpoint through shared storage.
    pub fn storage_load(&self, readers: usize, ckpt_bytes_per_reader: f64) -> f64 {
        let total = readers as f64 * (self.pyenv_bytes_per_container + ckpt_bytes_per_reader);
        total / self.storage_agg_bw_bytes
    }

    /// Replica broadcast of `bytes` of model state device-to-device.
    pub fn replica_transfer(&self, bytes: f64) -> f64 {
        bytes / self.d2d_bw_bytes
    }
}

/// Analytic training-step time for paper-scale workloads (7B/70B/175B):
/// 6 * params * tokens-per-device / (device FLOPs * MFU), plus a mild
/// collective-overhead term that grows with log2(n). Used for Tab. III's
/// "redone training" column at scales we cannot execute for real.
#[derive(Debug, Clone)]
pub struct StepTimeModel {
    pub device_flops: f64,
    pub mfu: f64,
    pub tokens_per_device: f64,
    pub comm_overhead_s_per_log2n: f64,
}

impl Default for StepTimeModel {
    fn default() -> Self {
        StepTimeModel {
            device_flops: 300.0e12,
            mfu: 0.40,
            tokens_per_device: 8192.0,
            comm_overhead_s_per_log2n: 1.2,
        }
    }
}

impl StepTimeModel {
    pub fn step_time_s(&self, params: f64, devices: usize) -> f64 {
        let compute = 6.0 * params * self.tokens_per_device
            / (self.device_flops * self.mfu);
        let comm = self.comm_overhead_s_per_log2n * (devices.max(2) as f64).log2();
        compute + comm
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_store_serial_is_linear() {
        let l = LatencyModel::default();
        let t1 = l.tcp_store_establishment(1000, 1);
        let t2 = l.tcp_store_establishment(2000, 1);
        assert!((t2 - l.tcp_store_setup_s) / (t1 - l.tcp_store_setup_s) > 1.9);
        // ~18s at 1000 devices
        assert!(t1 > 10.0 && t1 < 30.0);
    }

    #[test]
    fn wire_measurements_override_only_the_measured_constants() {
        let d = LatencyModel::default();
        let m = LatencyModel::with_wire(WireMeasurements {
            tcp_store_per_link_s: 0.052,
            detect_notice_s: 4.2,
        });
        assert_eq!(m.tcp_store_per_link_s, 0.052);
        assert!(m.detect_notice_min_s < 4.2 && m.detect_notice_max_s > 4.2);
        // untouched constants keep the paper fit
        assert_eq!(m.container_start_mean_s, d.container_start_mean_s);
        assert_eq!(m.ranktable_linear_s_per_dev, d.ranktable_linear_s_per_dev);
        // garbage measurements fall back to the defaults
        let g = LatencyModel::with_wire(WireMeasurements {
            tcp_store_per_link_s: -1.0,
            detect_notice_s: f64::NAN,
        });
        assert_eq!(g.tcp_store_per_link_s, d.tcp_store_per_link_s);
        assert_eq!(g.detect_notice_max_s, d.detect_notice_max_s);
    }

    #[test]
    fn tcp_store_parallel_is_much_flatter() {
        let l = LatencyModel::default();
        let serial = l.tcp_store_establishment(18_000, 1);
        let par = l.tcp_store_establishment(18_000, 64);
        assert!(serial / par > 30.0, "serial={serial} par={par}");
        assert!(par < 10.0);
    }

    #[test]
    fn ranktable_matches_table1_shape() {
        let l = LatencyModel::default();
        // paper: 8 / 31 / 60 / 176 / 249 s — require same order of
        // magnitude and strictly superlinear growth.
        let t1k = l.ranktable_original(1000);
        let t18k = l.ranktable_original(18_000);
        assert!(t1k > 2.0 && t1k < 20.0, "{t1k}");
        assert!(t18k > 150.0 && t18k < 400.0, "{t18k}");
        // shared-file stays sub-second
        assert!(l.ranktable_shared(1000) < 0.5);
        assert!(l.ranktable_shared(18_000) < 0.5);
    }

    #[test]
    fn container_start_respects_clamp() {
        let l = LatencyModel::default();
        let mut rng = Rng::new(3);
        for _ in 0..1000 {
            let t = l.container_start(&mut rng);
            assert!((l.container_start_min_s..=l.container_start_max_s).contains(&t));
        }
    }

    #[test]
    fn storage_load_scales_with_readers() {
        let l = LatencyModel::default();
        let a = l.storage_load(100, 1e9);
        let b = l.storage_load(1000, 1e9);
        assert!((b / a - 10.0).abs() < 1e-6);
    }

    #[test]
    fn step_time_model_reasonable_for_paper_scales() {
        let m = StepTimeModel::default();
        // 7B: paper reports ~6 s steps
        let t7b = m.step_time_s(7e9, 960);
        assert!(t7b > 2.0 && t7b < 25.0, "{t7b}");
        // 175B at 4800: paper reports ~49-79 s steps
        let t175 = m.step_time_s(175e9, 4800);
        assert!(t175 > 30.0 && t175 < 150.0, "{t175}");
        // larger model => longer step
        assert!(t175 > t7b);
    }
}
