//! # FlashRecovery — reproduction library
//!
//! A Rust + JAX + Pallas reproduction of *FlashRecovery: Fast and
//! Low-Cost Recovery from Failures for Large-Scale Training of LLMs*
//! (Zhang et al., 2025).
//!
//! The crate is the Layer-3 coordinator of a three-layer stack:
//!
//! * **Layer 1** (build-time Python): a Pallas flash-attention kernel —
//!   the training compute hot-spot (`python/compile/kernels/`).
//! * **Layer 2** (build-time Python): a decoder-only transformer with
//!   fwd/bwd and Adam, AOT-lowered to HLO text (`python/compile/`).
//! * **Layer 3** (this crate): the FlashRecovery system — active
//!   failure detection, scale-independent task restart, and
//!   checkpoint-free recovery within one step — plus every substrate it
//!   needs (cluster simulator, TCP store, checkpointing baseline,
//!   PJRT runtime, DP training engine).
//!
//! See `DESIGN.md` for the system inventory and the experiment index
//! mapping every paper table/figure to a bench target.

pub mod chaos;
pub mod checkpoint;
pub mod cluster;
pub mod comms;
pub mod config;
pub mod coordinator;
pub mod metrics;
pub mod recovery_model;
pub mod redundancy;
pub mod runtime;
pub mod telemetry;
pub mod training;
pub mod util;
