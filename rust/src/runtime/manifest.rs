//! The artifact manifest — the interop contract between the build-time
//! Python (L1/L2) and the Rust runtime (L3).
//!
//! `python/compile/aot.py` writes `artifacts/manifest.json` describing,
//! per model size: the model dimensions, the canonical parameter list
//! (name + shape, in positional order), the optimizer constants baked
//! into `opt_step`, and the artifact filenames.

use crate::util::Json;
use anyhow::{bail, Context, Result};
use std::path::{Path, PathBuf};

#[derive(Debug, Clone)]
pub struct ModelDims {
    pub name: String,
    pub n_layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_ff: usize,
    pub vocab: usize,
    pub seq: usize,
    /// Per-DP-rank micro-batch lowered into the artifact.
    pub batch: usize,
    pub param_count: u64,
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }
}

#[derive(Debug, Clone)]
pub struct AdamSpec {
    pub lr: f64,
    pub beta1: f64,
    pub beta2: f64,
    pub eps: f64,
    pub grad_clip: f64,
}

/// Manifest entry for one model size.
#[derive(Debug, Clone)]
pub struct ModelManifest {
    pub dims: ModelDims,
    pub params: Vec<ParamSpec>,
    pub optimizer: AdamSpec,
    /// artifact name ("init" | "fwd_bwd" | "opt_step" | "train_step")
    /// -> absolute file path.
    pub artifacts: std::collections::BTreeMap<String, PathBuf>,
}

impl ModelManifest {
    /// Total f32 elements across all parameters.
    pub fn total_elements(&self) -> usize {
        self.params.iter().map(|p| p.elements()).sum()
    }

    /// Bytes of one full model-state copy (params + m + v, f32).
    pub fn state_bytes(&self) -> usize {
        self.total_elements() * 4 * 3
    }

    pub fn artifact(&self, name: &str) -> Result<&PathBuf> {
        self.artifacts
            .get(name)
            .with_context(|| format!("artifact {name:?} missing from manifest"))
    }
}

/// Load one model size's manifest entry from `artifacts/manifest.json`.
pub fn load_manifest(artifacts_dir: &Path, size: &str) -> Result<ModelManifest> {
    let path = artifacts_dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {path:?} — run `make artifacts`"))?;
    let root = Json::parse(&text).context("parsing manifest.json")?;
    let entry = root.get("models").get(size);
    if entry.is_null() {
        bail!("model size {size:?} not in manifest — run `make artifacts`");
    }

    let c = entry.get("config");
    let req = |field: &str| -> Result<usize> {
        c.get(field)
            .as_usize()
            .with_context(|| format!("manifest config field {field:?}"))
    };
    let dims = ModelDims {
        name: size.to_string(),
        n_layers: req("n_layers")?,
        d_model: req("d_model")?,
        n_heads: req("n_heads")?,
        d_ff: req("d_ff")?,
        vocab: req("vocab")?,
        seq: req("seq")?,
        batch: req("batch")?,
        param_count: c.get("param_count").as_i64().unwrap_or(0) as u64,
    };

    let params = entry
        .get("params")
        .as_array()
        .context("manifest params")?
        .iter()
        .map(|p| -> Result<ParamSpec> {
            Ok(ParamSpec {
                name: p.get("name").as_str().context("param name")?.to_string(),
                shape: p
                    .get("shape")
                    .as_array()
                    .context("param shape")?
                    .iter()
                    .map(|d| d.as_usize().context("shape dim"))
                    .collect::<Result<_>>()?,
            })
        })
        .collect::<Result<Vec<_>>>()?;

    let o = entry.get("optimizer");
    let optimizer = AdamSpec {
        lr: o.get("lr").as_f64().unwrap_or(3e-4),
        beta1: o.get("beta1").as_f64().unwrap_or(0.9),
        beta2: o.get("beta2").as_f64().unwrap_or(0.999),
        eps: o.get("eps").as_f64().unwrap_or(1e-8),
        grad_clip: o.get("grad_clip").as_f64().unwrap_or(1.0),
    };

    let mut artifacts = std::collections::BTreeMap::new();
    if let Some(map) = entry.get("artifacts").as_object() {
        for (name, a) in map {
            let file = a.get("file").as_str().context("artifact file")?;
            artifacts.insert(name.clone(), artifacts_dir.join(file));
        }
    }
    for required in ["init", "fwd_bwd", "opt_step", "train_step"] {
        let p = artifacts
            .get(required)
            .with_context(|| format!("manifest missing artifact {required:?}"))?;
        if !p.is_file() {
            bail!("artifact file {p:?} does not exist — run `make artifacts`");
        }
    }

    // Sanity: parameter count from shapes must match the recorded total.
    let total: u64 = params.iter().map(|p| p.elements() as u64).sum();
    if dims.param_count != 0 && total != dims.param_count {
        bail!(
            "manifest param_count {} != sum of shapes {}",
            dims.param_count,
            total
        );
    }

    Ok(ModelManifest { dims, params, optimizer, artifacts })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::artifacts_dir;

    fn dir_or_skip() -> Option<std::path::PathBuf> {
        let d = artifacts_dir();
        if d.is_none() {
            eprintln!("skipping: artifacts/ not built (run `make artifacts`)");
        }
        d
    }

    #[test]
    fn loads_tiny_manifest() {
        let Some(dir) = dir_or_skip() else { return };
        let m = load_manifest(&dir, "tiny").unwrap();
        assert_eq!(m.dims.n_layers, 2);
        assert_eq!(m.dims.vocab, 256);
        assert_eq!(m.params.len(), 3 + 8 * m.dims.n_layers);
        assert_eq!(m.params[0].name, "embed");
        assert_eq!(m.params[0].shape, vec![256, 64]);
        assert_eq!(m.total_elements() as u64, m.dims.param_count);
        assert!(m.artifact("fwd_bwd").unwrap().is_file());
    }

    #[test]
    fn unknown_size_errors() {
        let Some(dir) = dir_or_skip() else { return };
        assert!(load_manifest(&dir, "huge").is_err());
    }

    #[test]
    fn state_bytes_is_three_copies() {
        let Some(dir) = dir_or_skip() else { return };
        let m = load_manifest(&dir, "tiny").unwrap();
        assert_eq!(m.state_bytes(), m.total_elements() * 12);
    }
}
