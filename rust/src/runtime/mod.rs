//! PJRT runtime: load the AOT HLO-text artifacts and execute them.
//!
//! This is the only place the `xla` crate is touched. Pattern (see
//! /opt/xla-example/load_hlo): `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `XlaComputation::from_proto` →
//! `client.compile` → `execute`. HLO *text* is the interchange format —
//! jax ≥ 0.5 emits protos with 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids.
//!
//! Python never runs here: after `make artifacts` the binary is
//! self-contained.

pub mod manifest;

pub use manifest::{load_manifest, AdamSpec, ModelDims, ModelManifest, ParamSpec};

/// Skip a `#[test]` body when the live plane (artifacts + real xla)
/// is unavailable — the offline-build default. With artifacts built
/// and the real `xla` crate swapped in, every guarded test runs.
#[macro_export]
macro_rules! require_live_plane {
    () => {
        if !$crate::runtime::live_plane_available() {
            eprintln!(
                "skipping {}: live training plane unavailable \
                 (run `make artifacts` + real xla backend)",
                module_path!()
            );
            return;
        }
    };
}

use anyhow::{bail, Context, Result};
use std::path::Path;
use std::sync::{Arc, Mutex, MutexGuard};

/// Global serialization lock for PJRT client operations.
///
/// The `xla` crate's `PjRtClient` is `Rc`-based and `execute()` clones
/// that Rc into every output buffer, so concurrent compile/execute/drop
/// across threads would race the non-atomic refcount. All such calls go
/// through this lock. (Pure `Literal` host objects carry no client
/// reference and need no locking.) On this single-core testbed the
/// serialization costs nothing.
fn xla_lock() -> MutexGuard<'static, ()> {
    static LOCK: Mutex<()> = Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Whether the live training plane can run here: compiled artifacts
/// present AND a real PJRT backend (the vendored `xla` stub's client
/// constructor fails by design — DESIGN.md §7). Tests and the chaos
/// live path use this to fall back / skip instead of erroring.
pub fn live_plane_available() -> bool {
    crate::util::artifacts_dir().is_some() && xla::PjRtClient::cpu().is_ok()
}

/// Thin wrapper over the PJRT CPU client. Cheap to clone (Arc inside).
#[derive(Clone)]
pub struct Runtime {
    client: Arc<xla::PjRtClient>,
}

// SAFETY: every client-touching operation goes through `xla_lock()`,
// so the inner Rc refcount is never mutated concurrently.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    pub fn cpu() -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Runtime { client: Arc::new(client) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one HLO-text artifact.
    pub fn compile_hlo_text(&self, path: &Path) -> Result<Executable> {
        let _g = xla_lock();
        let proto = xla::HloModuleProto::from_text_file(path)
            .with_context(|| format!("parsing HLO text {path:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {path:?}"))?;
        Ok(Executable { exe: Arc::new(exe) })
    }
}

/// A compiled computation. All artifacts are lowered with
/// `return_tuple=True`, so execution returns a single tuple literal
/// that [`Executable::run`] decomposes.
#[derive(Clone)]
pub struct Executable {
    exe: Arc<xla::PjRtLoadedExecutable>,
}

// SAFETY: `run` (the only client-touching method) holds `xla_lock()`
// for its whole extent, including the drop of intermediate buffers that
// clone the client Rc. The final drop of the executable happens after
// worker threads are joined (ModelBundle lives in an Arc owned by the
// controller).
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with literal inputs; returns the flattened output tuple.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.run_refs(&refs)
    }

    /// Execute with borrowed inputs — the hot path. Avoids deep-copying
    /// parameter/moment literals every step (§Perf optimization 1).
    pub fn run_refs(&self, inputs: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        let _g = xla_lock();
        let results = self.exe.execute::<&xla::Literal>(inputs)?;
        let tuple = results[0][0].to_literal_sync()?;
        Ok(tuple.to_tuple()?)
    }
}

/// All four executables for one model size, plus its manifest.
pub struct ModelBundle {
    pub manifest: ModelManifest,
    pub init: Executable,
    pub fwd_bwd: Executable,
    pub opt_step: Executable,
    pub train_step: Executable,
}

impl ModelBundle {
    /// Load and compile every artifact for `size` from `artifacts_dir`.
    pub fn load(rt: &Runtime, artifacts_dir: &Path, size: &str) -> Result<Self> {
        let manifest = load_manifest(artifacts_dir, size)?;
        let compile = |name: &str| -> Result<Executable> {
            rt.compile_hlo_text(manifest.artifact(name)?)
        };
        Ok(ModelBundle {
            init: compile("init")?,
            fwd_bwd: compile("fwd_bwd")?,
            opt_step: compile("opt_step")?,
            train_step: compile("train_step")?,
            manifest,
        })
    }

    /// Initialise parameters on-device from an i32 seed.
    pub fn init_params(&self, seed: i32) -> Result<Vec<xla::Literal>> {
        let out = self.init.run(&[xla::Literal::scalar(seed)])?;
        if out.len() != self.manifest.params.len() {
            bail!(
                "init returned {} tensors, manifest expects {}",
                out.len(),
                self.manifest.params.len()
            );
        }
        Ok(out)
    }

    /// Zero-filled optimizer moments matching the parameter shapes.
    pub fn zeros_like_params(&self) -> Result<Vec<xla::Literal>> {
        self.manifest
            .params
            .iter()
            .map(|p| {
                let data = vec![0f32; p.elements()];
                literal_f32(&p.shape, &data)
            })
            .collect()
    }

    /// `(loss, grads)` for one micro-batch: the pre-barrier phase.
    pub fn run_fwd_bwd(
        &self,
        params: &[xla::Literal],
        tokens: &xla::Literal,
    ) -> Result<(f32, Vec<xla::Literal>)> {
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(params.len() + 1);
        inputs.extend(params.iter());
        inputs.push(tokens);
        let mut out = self.fwd_bwd.run_refs(&inputs)?;
        if out.len() != params.len() + 1 {
            bail!("fwd_bwd returned {} tensors", out.len());
        }
        let loss = out.remove(0).get_first_element::<f32>()?;
        Ok((loss, out))
    }

    /// Adam update with *already-allreduced* grads: post-barrier phase.
    /// Returns (params', m', v').
    #[allow(clippy::type_complexity)]
    pub fn run_opt_step(
        &self,
        params: &[xla::Literal],
        m: &[xla::Literal],
        v: &[xla::Literal],
        step: f32,
        grads: &[xla::Literal],
    ) -> Result<(Vec<xla::Literal>, Vec<xla::Literal>, Vec<xla::Literal>)> {
        let n = params.len();
        let step_lit = xla::Literal::scalar(step);
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(4 * n + 1);
        for group in [params, m, v] {
            inputs.extend(group.iter());
        }
        inputs.push(&step_lit);
        inputs.extend(grads.iter());
        let mut out = self.opt_step.run_refs(&inputs)?;
        if out.len() != 3 * n {
            bail!("opt_step returned {} tensors, expected {}", out.len(), 3 * n);
        }
        let new_v = out.split_off(2 * n);
        let new_m = out.split_off(n);
        Ok((out, new_m, new_v))
    }

    /// Fused single-device step. Returns (loss, params', m', v').
    #[allow(clippy::type_complexity)]
    pub fn run_train_step(
        &self,
        params: &[xla::Literal],
        m: &[xla::Literal],
        v: &[xla::Literal],
        step: f32,
        tokens: &xla::Literal,
    ) -> Result<(f32, Vec<xla::Literal>, Vec<xla::Literal>, Vec<xla::Literal>)> {
        let n = params.len();
        let step_lit = xla::Literal::scalar(step);
        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(3 * n + 2);
        for group in [params, m, v] {
            inputs.extend(group.iter());
        }
        inputs.push(&step_lit);
        inputs.push(tokens);
        let mut out = self.train_step.run_refs(&inputs)?;
        if out.len() != 3 * n + 1 {
            bail!("train_step returned {} tensors", out.len());
        }
        let loss = out.remove(0).get_first_element::<f32>()?;
        let new_v = out.split_off(2 * n);
        let new_m = out.split_off(n);
        Ok((loss, out, new_m, new_v))
    }
}

/// Build an f32 literal of the given shape.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let expected: usize = shape.iter().product();
    if data.len() != expected {
        bail!("shape {shape:?} wants {expected} elements, got {}", data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|d| *d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims)?)
}

/// Build an i32 token literal of shape [batch, seq+1].
pub fn literal_tokens(batch: usize, seq_plus_1: usize, data: &[i32]) -> Result<xla::Literal> {
    if data.len() != batch * seq_plus_1 {
        bail!("tokens want {} elements, got {}", batch * seq_plus_1, data.len());
    }
    Ok(xla::Literal::vec1(data).reshape(&[batch as i64, seq_plus_1 as i64])?)
}

/// Deep-copy a literal (the xla crate's Literal is not Clone; we copy
/// through the raw host buffer).
pub fn clone_literal(lit: &xla::Literal) -> Result<xla::Literal> {
    use xla::ElementType::*;
    match lit.ty()? {
        F32 => {
            let data = lit.to_vec::<f32>()?;
            let shape = lit.array_shape()?;
            let dims: Vec<i64> = shape.dims().to_vec();
            Ok(xla::Literal::vec1(&data).reshape(&dims)?)
        }
        S32 => {
            let data = lit.to_vec::<i32>()?;
            let shape = lit.array_shape()?;
            let dims: Vec<i64> = shape.dims().to_vec();
            Ok(xla::Literal::vec1(&data).reshape(&dims)?)
        }
        other => bail!("clone_literal: unsupported element type {other:?}"),
    }
}

/// Extract an f32 literal's host data.
pub fn to_f32_vec(lit: &xla::Literal) -> Result<Vec<f32>> {
    Ok(lit.to_vec::<f32>()?)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::artifacts_dir;

    fn bundle() -> ModelBundle {
        let rt = Runtime::cpu().unwrap();
        let dir = artifacts_dir().expect("run `make artifacts` first");
        ModelBundle::load(&rt, &dir, "tiny").unwrap()
    }

    fn tokens_for(m: &ModelManifest, seed: u64) -> xla::Literal {
        let mut rng = crate::util::Rng::new(seed);
        let n = m.dims.batch * (m.dims.seq + 1);
        let data: Vec<i32> = (0..n)
            .map(|_| rng.below(m.dims.vocab as u64) as i32)
            .collect();
        literal_tokens(m.dims.batch, m.dims.seq + 1, &data).unwrap()
    }

    #[test]
    fn init_is_deterministic_and_shaped() {
        crate::require_live_plane!();
        let b = bundle();
        let p1 = b.init_params(0).unwrap();
        let p2 = b.init_params(0).unwrap();
        assert_eq!(p1.len(), b.manifest.params.len());
        for (i, spec) in b.manifest.params.iter().enumerate() {
            let got = p1[i].array_shape().unwrap();
            let want: Vec<i64> = spec.shape.iter().map(|d| *d as i64).collect();
            assert_eq!(got.dims(), &want[..], "{}", spec.name);
            assert_eq!(
                to_f32_vec(&p1[i]).unwrap(),
                to_f32_vec(&p2[i]).unwrap(),
                "{} not deterministic",
                spec.name
            );
        }
        let p3 = b.init_params(1).unwrap();
        // embed must differ across seeds
        assert_ne!(to_f32_vec(&p1[0]).unwrap(), to_f32_vec(&p3[0]).unwrap());
    }

    #[test]
    fn fwd_bwd_loss_near_uniform_and_grads_finite() {
        crate::require_live_plane!();
        let b = bundle();
        let params = b.init_params(0).unwrap();
        let tokens = tokens_for(&b.manifest, 7);
        let (loss, grads) = b.run_fwd_bwd(&params, &tokens).unwrap();
        let uniform = (b.manifest.dims.vocab as f32).ln();
        assert!((loss - uniform).abs() < 0.7, "loss {loss} vs ln(V)={uniform}");
        assert_eq!(grads.len(), params.len());
        for g in &grads {
            assert!(to_f32_vec(g).unwrap().iter().all(|x| x.is_finite()));
        }
    }

    #[test]
    fn split_step_equals_fused_step() {
        crate::require_live_plane!();
        let b = bundle();
        let params = b.init_params(3).unwrap();
        let m = b.zeros_like_params().unwrap();
        let v = b.zeros_like_params().unwrap();
        let tokens = tokens_for(&b.manifest, 11);

        // fused
        let (loss_f, pf, mf, vf) = b
            .run_train_step(&params, &m, &v, 1.0, &tokens)
            .unwrap();
        // split: fwd_bwd then opt_step (single rank, no allreduce)
        let (loss_s, grads) = b.run_fwd_bwd(&params, &tokens).unwrap();
        let (ps, ms, vs) = b.run_opt_step(&params, &m, &v, 1.0, &grads).unwrap();

        assert!((loss_f - loss_s).abs() < 1e-6);
        for ((a, b_), name) in pf.iter().zip(ps.iter()).zip(
            b.manifest.params.iter().map(|p| &p.name),
        ) {
            let av = to_f32_vec(a).unwrap();
            let bv = to_f32_vec(b_).unwrap();
            let max_err = av
                .iter()
                .zip(bv.iter())
                .map(|(x, y)| (x - y).abs())
                .fold(0.0f32, f32::max);
            assert!(max_err < 1e-6, "{name}: {max_err}");
        }
        // moments too
        for (a, b_) in mf.iter().zip(ms.iter()).chain(vf.iter().zip(vs.iter())) {
            assert_eq!(to_f32_vec(a).unwrap(), to_f32_vec(b_).unwrap());
        }
    }

    #[test]
    fn training_reduces_loss_on_fixed_batch() {
        crate::require_live_plane!();
        let b = bundle();
        let mut params = b.init_params(0).unwrap();
        let mut m = b.zeros_like_params().unwrap();
        let mut v = b.zeros_like_params().unwrap();
        let tokens = tokens_for(&b.manifest, 5);
        let mut first = None;
        let mut last = 0.0;
        for step in 1..=15 {
            let (loss, p2, m2, v2) = b
                .run_train_step(&params, &m, &v, step as f32, &tokens)
                .unwrap();
            params = p2;
            m = m2;
            v = v2;
            first.get_or_insert(loss);
            last = loss;
        }
        assert!(
            last < first.unwrap() - 0.3,
            "loss did not drop: {} -> {last}",
            first.unwrap()
        );
    }

    #[test]
    fn literal_helpers_validate_shapes() {
        assert!(literal_f32(&[2, 3], &[0.0; 6]).is_ok());
        assert!(literal_f32(&[2, 3], &[0.0; 5]).is_err());
        assert!(literal_tokens(2, 33, &vec![0; 66]).is_ok());
        assert!(literal_tokens(2, 33, &vec![0; 65]).is_err());
    }

    #[test]
    fn clone_literal_roundtrips() {
        let lit = literal_f32(&[4], &[1.0, 2.0, 3.0, 4.0]).unwrap();
        let c = clone_literal(&lit).unwrap();
        assert_eq!(to_f32_vec(&c).unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
    }
}
