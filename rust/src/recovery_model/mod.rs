//! The paper's §II recovery-overhead model (eqs. 1–5), plus a
//! Monte-Carlo failure simulator that validates the closed forms.
//!
//! Periodic checkpointing (eq. 1):
//!   F(t) = m (s0 + t/2) + (d/t) k0
//! Optimal interval (eq. 3):     t* = sqrt(2 d k0 / m)
//! Minimum overhead (eq. 4):     F_min = m s0 + sqrt(2 d k0 m)
//! FlashRecovery (eq. 5):        F = m (s0' + s1'),  k0 = 0, s1' ≈ one
//! step, s0' scale-independent.
//!
//! Time units are arbitrary but must be consistent (we use steps, with
//! `step_time = 1`; callers can also pass seconds throughout).

use crate::util::Rng;

/// Parameters of the periodic-checkpointing overhead model.
#[derive(Debug, Clone, Copy)]
pub struct OverheadParams {
    /// Fixed training period `d`.
    pub d: f64,
    /// Number of failures `m` within `d`.
    pub m: f64,
    /// Recovery overhead per failure `s0` (detect + restart + resume).
    pub s0: f64,
    /// Snapshot cost `k0` per checkpoint (non-overlapped).
    pub k0: f64,
}

impl OverheadParams {
    /// Eq. (1): total overhead at checkpoint interval `t`.
    pub fn total_overhead(&self, t: f64) -> f64 {
        assert!(t > 0.0);
        self.m * (self.s0 + t / 2.0) + (self.d / t) * self.k0
    }

    /// Eq. (3): the optimal checkpoint interval t*.
    pub fn optimal_interval(&self) -> f64 {
        (2.0 * self.d * self.k0 / self.m).sqrt()
    }

    /// Eq. (4): minimized overhead F_min.
    pub fn min_overhead(&self) -> f64 {
        self.m * self.s0 + (2.0 * self.d * self.k0 * self.m).sqrt()
    }
}

/// Eq. (5): FlashRecovery overhead — no checkpointing term, s1' fixed
/// at (roughly) one training step.
#[derive(Debug, Clone, Copy)]
pub struct FlashParams {
    pub m: f64,
    /// Scale-independent recovery overhead s0'.
    pub s0_prime: f64,
    /// Bounded recomputation s1' (≈ one step).
    pub s1_prime: f64,
}

impl FlashParams {
    pub fn total_overhead(&self) -> f64 {
        self.m * (self.s0_prime + self.s1_prime)
    }
}

/// Result of one Monte-Carlo run.
#[derive(Debug, Clone, Copy)]
pub struct McResult {
    pub mean_overhead: f64,
    pub mean_failures: f64,
}

/// Monte-Carlo validation of eq. (1): simulate Poisson failures over a
/// period `d` with checkpointing every `t`, accumulating detect/restart
/// overhead `s0` and recompute-to-checkpoint cost per failure.
///
/// The simulation measures *pure overhead time* (the training clock and
/// the failure clock are independent, matching the paper's model where
/// m is fixed for the period regardless of elongation).
pub fn monte_carlo_periodic(
    p: &OverheadParams,
    t: f64,
    runs: u32,
    seed: u64,
) -> McResult {
    let mut rng = Rng::new(seed ^ 0x0DE1);
    let rate = p.m / p.d;
    let mut total = 0.0;
    let mut failures = 0.0;
    for _ in 0..runs {
        let mut overhead = 0.0;
        // checkpoint cost paid every t units of training progress
        overhead += (p.d / t) * p.k0;
        // failures arrive Poisson(rate) over the period
        let mut clock = 0.0;
        loop {
            clock += rng.exponential(rate);
            if clock > p.d {
                break;
            }
            failures += 1.0;
            // progress since the last checkpoint is uniform in [0, t)
            let lost = rng.f64() * t;
            overhead += p.s0 + lost;
        }
        total += overhead;
    }
    McResult {
        mean_overhead: total / runs as f64,
        mean_failures: failures / runs as f64,
    }
}

/// Monte-Carlo for FlashRecovery (eq. 5): per failure, s0' + s1'.
pub fn monte_carlo_flash(p: &FlashParams, d: f64, runs: u32, seed: u64) -> McResult {
    let mut rng = Rng::new(seed ^ 0xF1A5);
    let rate = p.m / d;
    let mut total = 0.0;
    let mut failures = 0.0;
    for _ in 0..runs {
        let mut overhead = 0.0;
        let mut clock = 0.0;
        loop {
            clock += rng.exponential(rate);
            if clock > d {
                break;
            }
            failures += 1.0;
            overhead += p.s0_prime + p.s1_prime;
        }
        total += overhead;
    }
    McResult {
        mean_overhead: total / runs as f64,
        mean_failures: failures / runs as f64,
    }
}

/// Numerically locate the minimizing interval of eq. (1) by golden-
/// section search (cross-check for the closed-form t*).
pub fn numeric_optimal_interval(p: &OverheadParams, lo: f64, hi: f64) -> f64 {
    let phi = (5f64.sqrt() - 1.0) / 2.0;
    let (mut a, mut b) = (lo, hi);
    while b - a > 1e-9 * (1.0 + b.abs()) {
        let c = b - phi * (b - a);
        let d_ = a + phi * (b - a);
        if p.total_overhead(c) < p.total_overhead(d_) {
            b = d_;
        } else {
            a = c;
        }
    }
    (a + b) / 2.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop;

    fn params() -> OverheadParams {
        OverheadParams { d: 100_000.0, m: 20.0, s0: 50.0, k0: 5.0 }
    }

    #[test]
    fn closed_form_matches_numeric_optimum() {
        let p = params();
        let t_star = p.optimal_interval();
        let t_num = numeric_optimal_interval(&p, 1.0, 10_000.0);
        assert!(
            (t_star - t_num).abs() / t_star < 1e-4,
            "closed {t_star} vs numeric {t_num}"
        );
        // F(t*) equals F_min
        assert!((p.total_overhead(t_star) - p.min_overhead()).abs() < 1e-6);
    }

    #[test]
    fn overhead_is_convex_around_optimum() {
        let p = params();
        let t = p.optimal_interval();
        assert!(p.total_overhead(t * 0.5) > p.min_overhead());
        assert!(p.total_overhead(t * 2.0) > p.min_overhead());
    }

    #[test]
    fn paper_observation_1_higher_failure_rate_wants_smaller_interval() {
        let mut p = params();
        let t1 = p.optimal_interval();
        p.m *= 4.0;
        let t2 = p.optimal_interval();
        assert!((t2 - t1 / 2.0).abs() < 1e-9); // t* ∝ 1/sqrt(m)
    }

    #[test]
    fn paper_observation_2_bigger_k0_wants_larger_interval() {
        let mut p = params();
        let t1 = p.optimal_interval();
        p.k0 *= 4.0;
        let t2 = p.optimal_interval();
        assert!((t2 - 2.0 * t1).abs() < 1e-9); // t* ∝ sqrt(k0)
    }

    #[test]
    fn monte_carlo_validates_eq1() {
        let p = params();
        for t in [200.0, p.optimal_interval(), 2000.0] {
            let mc = monte_carlo_periodic(&p, t, 400, 7);
            let analytic = p.total_overhead(t);
            let rel = (mc.mean_overhead - analytic).abs() / analytic;
            assert!(
                rel < 0.05,
                "t={t}: mc {} vs analytic {analytic} (rel {rel})",
                mc.mean_overhead
            );
            assert!((mc.mean_failures - p.m).abs() < 2.0);
        }
    }

    #[test]
    fn monte_carlo_validates_eq5() {
        let f = FlashParams { m: 20.0, s0_prime: 90.0, s1_prime: 5.0 };
        let mc = monte_carlo_flash(&f, 100_000.0, 400, 11);
        let analytic = f.total_overhead();
        let rel = (mc.mean_overhead - analytic).abs() / analytic;
        assert!(rel < 0.05, "mc {} vs analytic {analytic}", mc.mean_overhead);
    }

    #[test]
    fn flash_beats_optimal_checkpointing_when_k0_positive() {
        // With the same s0 and one-step recompute, FlashRecovery's
        // overhead is below F_min for every k0 > 0 (the paper's core
        // claim: optimal RPO+RTO simultaneously).
        let p = params();
        let f = FlashParams { m: p.m, s0_prime: p.s0, s1_prime: 1.0 };
        assert!(f.total_overhead() < p.min_overhead());
    }

    #[test]
    fn prop_flash_dominates_for_all_params() {
        prop::check("flash <= optimal periodic", 300, |rng| {
            let d = rng.range_f64(1e3, 1e6);
            let m = rng.range_f64(1.0, 100.0);
            let s0 = rng.range_f64(10.0, 2000.0);
            let k0 = rng.range_f64(0.1, 100.0);
            let p = OverheadParams { d, m, s0, k0 };
            let f = FlashParams { m, s0_prime: s0, s1_prime: 1.0 };
            // F_min - F_flash = sqrt(2 d k0 m) - m * s1' ; flash wins
            // whenever the checkpoint term exceeds one step per failure.
            let wins = f.total_overhead() <= p.min_overhead();
            let expected = (2.0 * d * k0 * m).sqrt() >= m * 1.0;
            prop::assert_eq_prop(&wins, &expected)
        });
    }

    #[test]
    fn stability_example_from_paper() {
        // §II: (1-0.001)^100 ≈ 0.90479 and (1-0.0001)^1000 ≈ 0.90483 —
        // device-reliability gains cancel at scale.
        let p100 = (1.0f64 - 0.001).powi(100);
        let p1000 = (1.0f64 - 0.0001).powi(1000);
        assert!((p100 - 0.90479).abs() < 1e-4);
        assert!((p1000 - 0.90483).abs() < 1e-4);
        assert!((p100 - p1000).abs() < 1e-4);
    }
}
