//! Property tests for the store data plane (DESIGN.md §11): any
//! sequence of non-blocking ops observes the same responses and the
//! same final store state whether it is executed one-op-per-round-trip
//! or chunked into pipelined `Batch` frames — batching is a transport
//! optimization, never a semantic change.

use flashrecovery::comms::{Request, Response, TcpStoreClient, TcpStoreServer};
use flashrecovery::util::prop;

/// Generate one random non-blocking op over a small key pool (small
/// so ops collide and ordering actually matters).
fn gen_op(rng: &mut flashrecovery::util::Rng) -> Request {
    let key = format!("k{}", rng.below(8));
    match rng.below(6) {
        0 => Request::Set {
            key,
            value: (0..rng.below(24)).map(|_| rng.next_u64() as u8).collect(),
        },
        1 => Request::Get { key },
        2 => Request::Add { key, delta: rng.below(9) as i64 - 4 },
        3 => Request::Count,
        4 => Request::Heartbeat {
            rank: rng.below(4),
            incarnation: 1 + rng.below(3),
            step_tag: rng.below(100) as i64,
            device_code: -1,
        },
        _ => Request::Hello { client_id: rng.below(100) },
    }
}

/// Canonical observable state: every pool key's value, every pool
/// counter, and the key count.
fn observe(client: &mut TcpStoreClient) -> Vec<String> {
    let mut out = Vec::new();
    for i in 0..8 {
        let key = format!("k{i}");
        out.push(format!("{key}={:?}", client.get(&key).unwrap()));
        out.push(format!("{key}+={}", client.add(&key, 0).unwrap()));
    }
    out.push(format!("count={}", client.count().unwrap()));
    out
}

#[test]
fn batched_and_serial_execution_are_equivalent() {
    prop::check("batch == serial for any non-blocking op sequence", 30, |rng| {
        let ops: Vec<Request> = (0..rng.below(40) + 1).map(|_| gen_op(rng)).collect();

        // serial: one op per round-trip
        let serial_server = TcpStoreServer::start().map_err(|e| e.to_string())?;
        let mut sc =
            TcpStoreClient::connect(serial_server.addr()).map_err(|e| e.to_string())?;
        let mut serial_resps = Vec::with_capacity(ops.len());
        for op in &ops {
            serial_resps.push(sc.roundtrip(op.clone()).map_err(|e| e.to_string())?);
        }

        // batched: the same ops chunked into random-size Batch frames
        let batch_server = TcpStoreServer::start().map_err(|e| e.to_string())?;
        let mut bc =
            TcpStoreClient::connect(batch_server.addr()).map_err(|e| e.to_string())?;
        let mut batch_resps: Vec<Response> = Vec::with_capacity(ops.len());
        let mut rest = ops.as_slice();
        while !rest.is_empty() {
            let take = (rng.below(5) as usize + 1).min(rest.len());
            let (chunk, tail) = rest.split_at(take);
            batch_resps
                .extend(bc.batch(chunk.to_vec()).map_err(|e| e.to_string())?);
            rest = tail;
        }

        prop::assert_eq_prop(&serial_resps, &batch_resps)?;
        prop::assert_eq_prop(&observe(&mut sc), &observe(&mut bc))?;
        prop::assert_eq_prop(
            &serial_server.metrics_snapshot().gauge("store.keys"),
            &batch_server.metrics_snapshot().gauge("store.keys"),
        )?;
        prop::assert_eq_prop(
            &serial_server.metrics_snapshot().gauge("store.counters"),
            &batch_server.metrics_snapshot().gauge("store.counters"),
        )?;
        // logical message budgets are transport-independent: the
        // client op count and the server's executed-request count do
        // not change when ops are pipelined
        prop::assert_eq_prop(&(sc.ops_sent() >= ops.len() as u64), &true)?;
        prop::assert_eq_prop(&(bc.ops_sent() >= ops.len() as u64), &true)?;
        // frames, by contrast, must shrink under batching whenever a
        // chunk held more than one op
        let (batch_frames, serial_frames) = (
            batch_server.metrics_snapshot().counter("store.frames"),
            serial_server.metrics_snapshot().counter("store.frames"),
        );
        prop::assert_prop(
            batch_frames <= serial_frames,
            format!("batched frames {batch_frames} > serial frames {serial_frames}"),
        )
    });
}

#[test]
fn batched_heartbeats_equal_serial_heartbeats() {
    // The node-agent coalescing path: a Batch of Heartbeat ops must
    // leave the same beat table as the same beats pushed one by one
    // (including stale-incarnation suppression inside one batch).
    let beats = vec![
        Request::Heartbeat { rank: 1, incarnation: 2, step_tag: 5, device_code: -1 },
        Request::Heartbeat { rank: 1, incarnation: 1, step_tag: 99, device_code: -1 },
        Request::Heartbeat { rank: 2, incarnation: 1, step_tag: 7, device_code: 3 },
        Request::Heartbeat { rank: 1, incarnation: 2, step_tag: 6, device_code: -1 },
    ];

    let serial = TcpStoreServer::start().unwrap();
    let mut sc = TcpStoreClient::connect(serial.addr()).unwrap();
    for b in &beats {
        sc.roundtrip(b.clone()).unwrap();
    }

    let batched = TcpStoreServer::start().unwrap();
    let mut bc = TcpStoreClient::connect(batched.addr()).unwrap();
    let resps = bc.batch(beats).unwrap();
    assert!(resps.iter().all(|r| *r == Response::Ok));

    let canon = |server: &TcpStoreServer| {
        let mut v: Vec<(u64, u64, i64, i64)> = server
            .beats()
            .iter()
            .map(|b| (b.rank, b.incarnation, b.step_tag, b.device_code))
            .collect();
        v.sort_unstable();
        v
    };
    assert_eq!(canon(&serial), canon(&batched));
    assert_eq!(canon(&serial), vec![(1, 2, 6, -1), (2, 1, 7, 3)]);
}
