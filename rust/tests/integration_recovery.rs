//! Cross-module integration tests: the full controller + engine +
//! runtime stack under failure injection.

use flashrecovery::cluster::failure::FailureKind;
use flashrecovery::coordinator::{ControllerConfig, SharedRanktable};
use flashrecovery::training::worker::{FailurePlan, Phase};
use flashrecovery::training::TrainingEngine;
use flashrecovery::util::temp_dir;
use std::sync::OnceLock;
use std::time::Duration;

/// One engine per test binary: artifact compilation is the expensive
/// part and the bundle is safely shared.
fn engine() -> &'static TrainingEngine {
    static ENGINE: OnceLock<TrainingEngine> = OnceLock::new();
    ENGINE.get_or_init(|| TrainingEngine::load("tiny").expect("run `make artifacts`"))
}

#[test]
fn two_sequential_failures_both_recover() {
    flashrecovery::require_live_plane!();
    let mut cfg = ControllerConfig::flash(3, 14);
    cfg.failures = vec![
        FailurePlan { rank: 1, step: 4, phase: Phase::FwdBwd, kind: FailureKind::Segfault },
        FailurePlan { rank: 2, step: 9, phase: Phase::OptStep, kind: FailureKind::DeviceMemory },
    ];
    let report = engine().run(cfg).unwrap();
    assert_eq!(report.final_step, 14);
    assert_eq!(report.recoveries.len(), 2);
    assert_eq!(report.recoveries[0].resume_step, 4); // fwd/bwd -> i
    assert_eq!(report.recoveries[1].resume_step, 10); // optimizer -> i+1
    assert!(report.recoveries.iter().all(|r| r.lost_steps == 0));
    assert_eq!(report.final_param_divergence, 0.0);
}

#[test]
fn replacement_rank_can_fail_again_later() {
    flashrecovery::require_live_plane!();
    // rank 1 dies at step 3; later rank 0 dies at step 7 — the fleet
    // that recovers the second failure contains a replacement member.
    let mut cfg = ControllerConfig::flash(2, 10);
    cfg.failures = vec![
        FailurePlan { rank: 1, step: 3, phase: Phase::FwdBwd, kind: FailureKind::Oom },
        FailurePlan { rank: 0, step: 7, phase: Phase::FwdBwd, kind: FailureKind::Segfault },
    ];
    let report = engine().run(cfg).unwrap();
    assert_eq!(report.final_step, 10);
    assert_eq!(report.recoveries.len(), 2);
    assert_eq!(report.final_param_divergence, 0.0);
}

#[test]
fn shared_ranktable_is_updated_across_recovery() {
    flashrecovery::require_live_plane!();
    let dir = temp_dir("rt-e2e").unwrap();
    let rt_path = dir.join("ranktable.json");
    let mut cfg = ControllerConfig::flash(2, 8);
    cfg.ranktable_path = Some(rt_path.clone());
    cfg.failures = vec![FailurePlan {
        rank: 1,
        step: 3,
        phase: Phase::FwdBwd,
        kind: FailureKind::Network,
    }];
    let report = engine().run(cfg).unwrap();
    assert_eq!(report.recoveries.len(), 1);

    // Devices load the table O(1) from the shared file; after the
    // substitution its version is bumped and rank 1 points elsewhere.
    let table = SharedRanktable::new(&rt_path).load().unwrap();
    assert!(table.version >= 2, "substitution must bump version");
    table.validate().unwrap();
    assert_eq!(table.entries.len(), 2);
    assert_ne!(table.entries[1].addr, "127.0.0.1:29001".to_string());
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn vanilla_without_checkpoint_restarts_from_scratch() {
    flashrecovery::require_live_plane!();
    let dir = temp_dir("vanilla-scratch").unwrap();
    let mut cfg =
        ControllerConfig::vanilla(2, 8, 0 /* no checkpoints */, Duration::from_millis(400));
    cfg.ckpt_dir = dir.clone();
    cfg.failures = vec![FailurePlan {
        rank: 0,
        step: 5,
        phase: Phase::FwdBwd,
        kind: FailureKind::Segfault,
    }];
    let report = engine().run(cfg).unwrap();
    assert_eq!(report.final_step, 8);
    let r = &report.recoveries[0];
    assert_eq!(r.resume_step, 0, "no checkpoint -> restart from step 0");
    assert_eq!(r.lost_steps, 5);
    assert_eq!(report.final_param_divergence, 0.0);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn vanilla_detection_waits_for_timeout_flash_does_not() {
    flashrecovery::require_live_plane!();
    let timeout = Duration::from_millis(600);
    let fail = FailurePlan {
        rank: 1,
        step: 3,
        phase: Phase::FwdBwd,
        kind: FailureKind::Segfault,
    };

    let mut v = ControllerConfig::vanilla(2, 6, 2, timeout);
    let vdir = temp_dir("vanilla-det").unwrap();
    v.ckpt_dir = vdir.clone();
    v.failures = vec![fail];
    let vrep = engine().run(v).unwrap();
    let vdet = vrep.recoveries[0].detection_s;

    let mut f = ControllerConfig::flash(2, 6);
    f.heartbeat_interval = Duration::from_millis(50);
    f.failures = vec![fail];
    let frep = engine().run(f).unwrap();
    let fdet = frep.recoveries[0].detection_s;

    assert!(
        vdet >= 0.5,
        "vanilla must wait out the collective timeout ({vdet}s)"
    );
    assert!(fdet < 0.5, "flash detection must be sub-timeout ({fdet}s)");
    assert!(fdet < vdet);
    std::fs::remove_dir_all(vdir).ok();
}

#[test]
fn dp4_failure_recovers_with_three_survivors() {
    flashrecovery::require_live_plane!();
    let mut cfg = ControllerConfig::flash(4, 8);
    cfg.failures = vec![FailurePlan {
        rank: 2,
        step: 4,
        phase: Phase::OptStep,
        kind: FailureKind::AiCore,
    }];
    let report = engine().run(cfg).unwrap();
    assert_eq!(report.final_step, 8);
    assert_eq!(report.recoveries.len(), 1);
    assert_eq!(report.recoveries[0].resume_step, 5);
    assert_eq!(report.final_param_divergence, 0.0);
}

#[test]
fn hardware_failure_reported_via_device_plugin_with_kind() {
    flashrecovery::require_live_plane!();
    let mut cfg = ControllerConfig::flash(2, 6);
    cfg.failures = vec![FailurePlan {
        rank: 1,
        step: 3,
        phase: Phase::FwdBwd,
        kind: FailureKind::Driver,
    }];
    let report = engine().run(cfg).unwrap();
    let r = &report.recoveries[0];
    assert!(r.via_device_plugin);
    assert_eq!(r.kind, FailureKind::Driver);
}

#[test]
fn simultaneous_two_rank_failure_recovers_from_single_survivor() {
    flashrecovery::require_live_plane!();
    // dp=3, ranks 1 and 2 die at the same step: both are replaced and
    // restored from rank 0's replica in one episode.
    let mut cfg = ControllerConfig::flash(3, 8);
    cfg.failures = vec![
        FailurePlan { rank: 1, step: 4, phase: Phase::FwdBwd, kind: FailureKind::Network },
        FailurePlan { rank: 2, step: 4, phase: Phase::FwdBwd, kind: FailureKind::Segfault },
    ];
    let report = engine().run(cfg).unwrap();
    assert_eq!(report.final_step, 8);
    // one or two episodes depending on scan timing; all ranks recovered
    let total_failed: usize = report
        .recoveries
        .iter()
        .map(|r| r.failed_ranks.len())
        .sum();
    assert_eq!(total_failed, 2);
    assert!(report.recoveries.iter().all(|r| r.lost_steps == 0));
    assert_eq!(report.final_param_divergence, 0.0);
}

#[test]
fn one_failure_per_zero_shard_group_restores_from_distinct_replicas() {
    flashrecovery::require_live_plane!();
    // dp=4 sharded 2 ways: shard groups {0,2} and {1,3}. Kill one rank
    // per group at the same step; the streaming restore must source
    // each lost shard from the surviving replica of the same group
    // (two distinct sources, parallel transfers) and end bit-exact.
    let mut cfg = ControllerConfig::flash(4, 8);
    cfg.zero_shards = 2;
    cfg.failures = vec![
        FailurePlan { rank: 0, step: 4, phase: Phase::FwdBwd, kind: FailureKind::Network },
        FailurePlan { rank: 1, step: 4, phase: Phase::FwdBwd, kind: FailureKind::Segfault },
    ];
    let report = engine().run(cfg).unwrap();
    assert_eq!(report.final_step, 8);
    assert_eq!(report.final_param_divergence, 0.0);
    let restores: Vec<_> = report
        .recoveries
        .iter()
        .flat_map(|r| r.shard_restores.iter())
        .collect();
    assert!(!restores.is_empty(), "flash recovery must stream state");
    for s in &restores {
        assert!(s.bytes > 0);
        assert_ne!(s.source, s.target);
        // replica-location invariant: source and target share a shard
        assert_eq!(s.source % 2, s.target % 2, "{s:?}");
    }
    // when both ranks fail in one episode, the two lost shards must be
    // served by two distinct surviving replicas
    for r in &report.recoveries {
        if r.failed_ranks.len() == 2 {
            let mut srcs: Vec<usize> =
                r.shard_restores.iter().map(|s| s.source).collect();
            srcs.sort_unstable();
            srcs.dedup();
            assert_eq!(srcs.len(), 2, "distinct replica per lost shard: {r:?}");
        }
    }
}

#[test]
fn whole_dp_group_loss_falls_back_to_checkpoint_path() {
    flashrecovery::require_live_plane!();
    // Paper §III-G limitation 1: if every replica fails simultaneously
    // there is no source — FlashRecovery must fall back to the
    // checkpoint path (here: no checkpoint -> restart from scratch).
    let dir = temp_dir("group-loss").unwrap();
    let mut cfg = ControllerConfig::flash(2, 6);
    cfg.ckpt_dir = dir.clone();
    cfg.failures = vec![
        FailurePlan { rank: 0, step: 3, phase: Phase::FwdBwd, kind: FailureKind::Network },
        FailurePlan { rank: 1, step: 3, phase: Phase::FwdBwd, kind: FailureKind::Network },
    ];
    let report = engine().run(cfg).unwrap();
    assert_eq!(report.final_step, 6);
    let r = report.recoveries.last().unwrap();
    assert_eq!(r.mode, flashrecovery::config::RecoveryMode::Vanilla);
    assert_eq!(r.resume_step, 0, "no surviving replica, no checkpoint");
    assert!(r.lost_steps > 0);
    assert_eq!(report.final_param_divergence, 0.0);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn controller_config_from_job_config() {
    use flashrecovery::config::{JobConfig, ParallelismConfig, RecoveryMode};
    let mut job = JobConfig::default();
    job.model = "tiny".into();
    job.parallelism = ParallelismConfig::dp(2);
    job.steps = 5;
    job.seed = 9;
    job.cluster.heartbeat_interval_s = 0.05;
    job.checkpoint.interval_steps = 2;
    job.recovery.mode = RecoveryMode::Vanilla;
    let cfg = ControllerConfig::from_job(&job).unwrap();
    assert_eq!(cfg.dp, 2);
    assert_eq!(cfg.steps, 5);
    assert_eq!(cfg.seed, 9);
    assert_eq!(cfg.ckpt_interval, 2);
    assert_eq!(cfg.mode, RecoveryMode::Vanilla);

    // model-parallel topologies are rejected on the real plane
    job.parallelism = ParallelismConfig::new(2, 2, 1);
    job.cluster.num_nodes = 8;
    assert!(ControllerConfig::from_job(&job).is_err());

    // and a full run driven by the job config works end to end
    flashrecovery::require_live_plane!();
    job.parallelism = ParallelismConfig::dp(2);
    job.recovery.mode = RecoveryMode::Flash;
    job.checkpoint.interval_steps = 0;
    let cfg = ControllerConfig::from_job(&job).unwrap();
    let report = engine().run(cfg).unwrap();
    assert_eq!(report.final_step, 5);
}

#[test]
fn software_failure_classified_by_monitor_process() {
    flashrecovery::require_live_plane!();
    let mut cfg = ControllerConfig::flash(2, 6);
    cfg.failures = vec![FailurePlan {
        rank: 0,
        step: 2,
        phase: Phase::FwdBwd,
        kind: FailureKind::Oom,
    }];
    let report = engine().run(cfg).unwrap();
    let r = &report.recoveries[0];
    assert!(!r.via_device_plugin, "software death has no plugin report");
}
