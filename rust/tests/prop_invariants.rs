//! System-level property tests (own mini-prop harness; proptest is
//! unavailable offline — see DESIGN.md substitutions).

use flashrecovery::checkpoint::{decode_snapshot, encode_snapshot, Snapshot};
use flashrecovery::cluster::{simulate_flash, simulate_vanilla, ScenarioConfig};
use flashrecovery::config::ParallelismConfig;
use flashrecovery::coordinator::step_tag::{decide, plan_restore, TagDecision};
use flashrecovery::recovery_model::{FlashParams, OverheadParams};
use flashrecovery::util::{prop, Json, Rng};

#[test]
fn prop_snapshot_bytes_roundtrip() {
    prop::check("snapshot byte roundtrip", 100, |rng| {
        let n_tensors = 1 + rng.below(6) as usize;
        let tensors: Vec<Vec<f32>> = (0..n_tensors)
            .map(|_| {
                let len = rng.below(200) as usize;
                (0..len).map(|_| (rng.f64() as f32 - 0.5) * 1e3).collect()
            })
            .collect();
        let snap = Snapshot { step: rng.next_u64() % 10_000, tensors };
        let back = decode_snapshot(&encode_snapshot(&snap)).map_err(|e| e.to_string())?;
        prop::assert_eq_prop(&back, &snap)
    });
}

#[test]
fn prop_snapshot_corruption_always_detected() {
    prop::check("snapshot corruption detected", 100, |rng| {
        let tensors = vec![vec![1.5f32; 16], vec![-2.0; 8]];
        let snap = Snapshot { step: 3, tensors };
        let mut bytes = encode_snapshot(&snap);
        let idx = rng.below(bytes.len() as u64) as usize;
        let bit = 1u8 << rng.below(8);
        bytes[idx] ^= bit;
        prop::assert_prop(
            decode_snapshot(&bytes).is_err(),
            format!("flipping bit {bit:#x} at byte {idx} went undetected"),
        )
    });
}

#[test]
fn prop_json_roundtrip_fuzz() {
    fn random_json(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.bool(0.5)),
            2 => Json::Num((rng.next_u32() as f64 / 64.0).floor()),
            3 => {
                let len = rng.below(8) as usize;
                Json::Str(
                    (0..len)
                        .map(|_| char::from_u32(0x20 + rng.next_u32() % 0x5e).unwrap())
                        .collect(),
                )
            }
            4 => Json::Array(
                (0..rng.below(4)).map(|_| random_json(rng, depth - 1)).collect(),
            ),
            _ => {
                let mut o = Json::object();
                for i in 0..rng.below(4) {
                    o.set(&format!("k{i}"), random_json(rng, depth - 1));
                }
                o
            }
        }
    }
    prop::check("json roundtrip", 200, |rng| {
        let v = random_json(rng, 3);
        let back = Json::parse(&v.render()).map_err(|e| e.to_string())?;
        prop::assert_eq_prop(&back, &v)?;
        let pretty = Json::parse(&v.render_pretty()).map_err(|e| e.to_string())?;
        prop::assert_eq_prop(&pretty, &v)
    });
}

#[test]
fn prop_flash_total_beats_vanilla_at_any_scale() {
    prop::check("flash < vanilla for all scales", 40, |rng| {
        let devices = 32 + rng.below(10_000) as usize;
        let params = [7e9, 70e9, 175e9][rng.below(3) as usize];
        let seed = rng.next_u64();
        let cfg = ScenarioConfig::paper(devices, params, seed);
        let f = simulate_flash(&cfg);
        let v = simulate_vanilla(&cfg);
        prop::assert_prop(
            f.total_s < v.total_s,
            format!("{devices} devices: flash {} >= vanilla {}", f.total_s, v.total_s),
        )
    });
}

#[test]
fn prop_flash_breakdown_internally_consistent() {
    prop::check("breakdown consistency", 60, |rng| {
        let devices = 32 + rng.below(18_000) as usize;
        let cfg = ScenarioConfig::paper(devices, 70e9, rng.next_u64());
        let b = simulate_flash(&cfg);
        prop::assert_prop(b.detection_s > 0.0, "detection <= 0")?;
        prop::assert_prop(b.restart_s > 0.0, "restart <= 0")?;
        prop::assert_close(b.redone_s, b.step_time_s / 2.0, 1e-9)?;
        prop::assert_close(b.total_s, b.detection_s + b.restart_s + b.redone_s, 1e-9)
    });
}

#[test]
fn prop_step_tag_decision_total_function() {
    // decide() must handle every tag mix without losing updates or
    // acting while an optimizer is in flight.
    prop::check("step-tag totality", 300, |rng| {
        let i = rng.below(10_000) as i64;
        let n = 1 + rng.below(10) as usize;
        let tags: Vec<i64> = (0..n)
            .map(|_| match rng.below(3) {
                0 => i,
                1 => i + 1,
                _ => -1,
            })
            .collect();
        match decide(&tags) {
            TagDecision::Wait => {
                prop::assert_prop(tags.contains(&-1), "waited with no -1 tag")
            }
            TagDecision::Act { resume_step } => {
                prop::assert_prop(!tags.contains(&-1), "acted during optimizer")?;
                prop::assert_eq_prop(&(resume_step as i64), tags.iter().max().unwrap())
            }
        }
    });
}

#[test]
fn prop_restore_plan_covers_everyone_with_zero_topology() {
    // Combined invariant: for any DP/ZeRO topology with replication,
    // any single-node failure set has recovery sources, and the restore
    // plan partitions the survivors.
    prop::check("zero-topology restore", 200, |rng| {
        let dp = 2 + rng.below(6) as usize;
        let divisors: Vec<usize> =
            (1..=dp).filter(|s| dp % s == 0 && dp / s >= 2).collect();
        let shards = *rng.choose(&divisors);
        let p = ParallelismConfig::dp(dp).with_zero(shards);
        let failed = rng.below(dp as u64) as usize;
        prop::assert_prop(
            p.can_recover(&[failed]),
            format!("dp={dp} shards={shards} failed={failed} unrecoverable"),
        )?;
        // survivor states all equal -> plan_restore has no laggards
        let steps: Vec<(usize, u64)> = (0..dp)
            .filter(|r| *r != failed)
            .map(|r| (r, 7))
            .collect();
        let (resume, sources, behind) = plan_restore(&steps);
        prop::assert_eq_prop(&resume, &7)?;
        prop::assert_eq_prop(&(sources.len() + behind.len() + 1), &dp)?;
        prop::assert_prop(behind.is_empty(), "unexpected laggards")
    });
}

#[test]
fn prop_overhead_model_convexity_and_optimum() {
    prop::check("F(t) convex with min at t*", 200, |rng| {
        let p = OverheadParams {
            d: rng.range_f64(1e3, 1e6),
            m: rng.range_f64(1.0, 200.0),
            s0: rng.range_f64(1.0, 5e3),
            k0: rng.range_f64(0.01, 200.0),
        };
        let t_star = p.optimal_interval();
        let f_min = p.min_overhead();
        prop::assert_close(p.total_overhead(t_star), f_min, 1e-9)?;
        for mult in [0.3, 0.7, 1.5, 3.0] {
            prop::assert_prop(
                p.total_overhead(t_star * mult) >= f_min - 1e-9,
                format!("F({mult} t*) < F_min"),
            )?;
        }
        // eq. 5 with one-step recompute dominates whenever the
        // checkpointing term would exceed m steps
        let flash = FlashParams { m: p.m, s0_prime: p.s0, s1_prime: 1.0 };
        let expected = (2.0 * p.d * p.k0 * p.m).sqrt() >= p.m;
        prop::assert_eq_prop(&(flash.total_overhead() <= f_min), &expected)
    });
}
