//! Chaos-engine property tests: the determinism contract and campaign
//! invariants, across the built-in scenario library.

use flashrecovery::chaos::{evaluate, library, passed, run_campaign, ScenarioSpec};
use flashrecovery::util::prop;

/// Acceptance contract: for library scenarios × seeds, two runs of the
/// same (spec, seed) produce byte-identical journals.
#[test]
fn determinism_three_scenarios_by_three_seeds() {
    for name in ["single_fault", "rolling_cascade", "failure_during_recovery"] {
        let spec = library::by_name(name, 256).unwrap();
        for seed in [1u64, 99, 123_456_789] {
            let (r1, j1) = run_campaign(&spec, seed).unwrap();
            let (r2, j2) = run_campaign(&spec, seed).unwrap();
            let (a, b) = (j1.render(), j2.render());
            assert_eq!(a, b, "{name} seed {seed}: journals diverged");
            assert!(!a.is_empty());
            assert_eq!(r1.steps_completed, r2.steps_completed);
            assert_eq!(r1.total_downtime_s, r2.total_downtime_s);
        }
    }
}

#[test]
fn determinism_survives_spec_json_roundtrip() {
    // A spec reloaded from its own JSON must replay the same journal —
    // the spec hash is the identity, not the in-memory object.
    let spec = library::by_name("flaky_node", 512).unwrap();
    let reloaded = ScenarioSpec::from_json(&spec.to_json()).unwrap();
    let (_, j1) = run_campaign(&spec, 42).unwrap();
    let (_, j2) = run_campaign(&reloaded, 42).unwrap();
    assert_eq!(j1.render(), j2.render());
}

#[test]
fn whole_library_passes_assertions_across_seeds_and_scales() {
    for devices in [256usize, 1024] {
        for spec in library::all(devices) {
            for seed in [2u64, 31, 77] {
                let (report, _) = run_campaign(&spec, seed).unwrap();
                let outcomes = evaluate(&spec.assertions, &report);
                assert!(
                    passed(&outcomes),
                    "{} @ {devices} seed {seed}: {:?}",
                    spec.name,
                    outcomes.iter().filter(|o| !o.pass).collect::<Vec<_>>()
                );
            }
        }
    }
}

#[test]
fn prop_campaign_invariants_hold_for_random_seeds() {
    // For any seed: recoveries are time-ordered and non-overlapping,
    // downtime is bounded by (end - 0), and node accounting closes
    // (running + spare + faulty == active + spares).
    prop::check("campaign invariants", 40, |rng| {
        let specs = library::all(256);
        let spec = &specs[rng.below(specs.len() as u64) as usize];
        let seed = rng.next_u64();
        let (report, journal) =
            run_campaign(spec, seed).map_err(|e| e.to_string())?;

        let mut prev_end = 0.0f64;
        for r in &report.recoveries {
            prop::assert_prop(
                r.started_s >= prev_end - 1e-9,
                format!("overlapping recoveries at {}", r.started_s),
            )?;
            prop::assert_prop(r.restart_s >= 0.0, "negative restart")?;
            prop::assert_prop(
                r.detection_s > 0.0,
                "non-positive detection",
            )?;
            prev_end = r.ended_s;
        }
        prop::assert_prop(
            report.total_downtime_s <= report.end_s + 1e-6,
            format!(
                "downtime {} exceeds campaign span {}",
                report.total_downtime_s, report.end_s
            ),
        )?;
        let active = spec.cluster.active_nodes();
        let accounted = report.final_running_nodes
            + report.spares_left
            + report.unrecovered_nodes;
        prop::assert_eq_prop(&accounted, &(active + spec.cluster.spare_nodes))?;
        prop::assert_prop(
            journal.events().len() >= 2,
            "journal missing campaign_start/campaign_end",
        )
    });
}

#[test]
fn prop_seed_changes_move_the_journal() {
    // Different seeds almost surely produce different journals (the
    // RNG feeds victim picks and latency draws).
    prop::check("seed sensitivity", 20, |rng| {
        let spec = library::by_name("single_fault", 256).unwrap();
        let s1 = rng.next_u64();
        let s2 = s1.wrapping_add(1 + rng.below(1000));
        let (_, j1) = run_campaign(&spec, s1).map_err(|e| e.to_string())?;
        let (_, j2) = run_campaign(&spec, s2).map_err(|e| e.to_string())?;
        prop::assert_prop(
            j1.render() != j2.render(),
            format!("seeds {s1} and {s2} gave identical journals"),
        )
    });
}

/// The two scenarios the acceptance criteria call out must complete —
/// no panic, no deadlock (bounded queue drain) — and recover fully.
#[test]
fn cascade_and_mid_recovery_failures_complete_cleanly() {
    for name in ["rolling_cascade", "failure_during_recovery"] {
        for seed in [3u64, 17, 1001] {
            let spec = library::by_name(name, 256).unwrap();
            let (report, _) = run_campaign(&spec, seed).unwrap();
            assert_eq!(
                report.unrecovered_nodes, 0,
                "{name} seed {seed} left nodes unrecovered"
            );
            assert!(report.merged_recoveries >= 1, "{name} seed {seed}");
            assert!(report.end_s.is_finite());
        }
    }
}
