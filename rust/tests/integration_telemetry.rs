//! Flight-recorder end-to-end test (DESIGN.md §12): the live
//! detection → rendezvous → restore chain over real sockets must
//! stitch into ONE trace — three phase spans sharing the episode's
//! trace_id, nested under its root span, with non-overlapping wall
//! intervals that reconcile against the outcome's measured phase
//! durations — and the Chrome export of that trace must be
//! schema-valid.
//!
//! The recorder and registry are process-global and tests run in
//! parallel, so this test only ever *enables* recording and filters
//! every assertion by its own episode's trace_id.

use flashrecovery::chaos::{drive_live_detection, library};
use flashrecovery::telemetry::trace;

#[test]
fn silent_hang_episode_stitches_into_one_trace() {
    trace::set_recording(true);
    let spec = library::by_name("silent_hang", 256).unwrap();
    let episodes = drive_live_detection(&spec).unwrap();
    assert_eq!(episodes.len(), 1);
    let ep = &episodes[0];
    assert_ne!(ep.trace_id, 0, "recorder on => the episode must carry a trace id");

    let spans = trace::spans_for(ep.trace_id);
    let root = spans
        .iter()
        .find(|s| s.name == "episode" && s.parent == 0)
        .expect("episode root span");
    let phase = |name: &str| {
        spans
            .iter()
            .find(|s| s.name == name && s.parent == root.span_id)
            .unwrap_or_else(|| panic!("no {name} span under the episode root"))
    };
    let detect = phase("detection");
    let rebuild = phase("rebuild");
    let restore = phase("restore");

    // phases run sequentially: strictly ordered, non-overlapping wall
    // intervals, all inside the root's interval
    assert!(detect.end_us <= rebuild.start_us, "detection overlaps rebuild");
    assert!(rebuild.end_us <= restore.start_us, "rebuild overlaps restore");
    assert!(root.start_us <= detect.start_us && restore.end_us <= root.end_us);

    // span durations reconcile with the outcome's measured phase
    // fields (±1ms): the spans open/close adjacent to the same Instant
    // reads the outcome reports. detection_s is a measured
    // heartbeat→detection latency, not a wall interval, so only
    // rebuild/restore reconcile.
    for (span, wall, name) in
        [(rebuild, ep.rebuild_s, "rebuild"), (restore, ep.restore_s, "restore")]
    {
        let dur = span.duration_s();
        assert!(
            (dur - wall).abs() <= 1e-3,
            "{name}: span {dur:.4}s vs outcome {wall:.4}s"
        );
    }
    assert!(
        root.duration_s() >= ep.rebuild_s + ep.restore_s,
        "episode root must cover its phases"
    );

    // the state transfer stitched in over the wire: the source's serve
    // span nests under the restore span (via StreamConfig::trace), the
    // target's fetch span under the serve span (via the in-band
    // FRAME_TRACE frame) — all on the same trace
    let serve = spans
        .iter()
        .find(|s| s.name == "serve_state")
        .expect("serve_state span on the episode trace");
    assert_eq!(serve.parent, restore.span_id, "serve must nest under restore");
    let fetch = spans
        .iter()
        .find(|s| s.name == "fetch_state")
        .expect("fetch_state span on the episode trace");
    assert_eq!(fetch.parent, serve.span_id, "fetch must stitch under serve");

    // mid-episode introspection: the Stats wire op's snapshot landed
    // on the trace as a store-stats event
    let events = trace::events_for(ep.trace_id);
    let stats = events
        .iter()
        .find(|e| e.name == "store-stats")
        .expect("store-stats event on the episode trace");
    assert!(stats.detail.contains("requests="), "detail: {:?}", stats.detail);

    // and the Chrome export of exactly this trace is schema-valid
    let doc = trace::chrome_trace(ep.trace_id);
    trace::validate_chrome_trace(&doc).unwrap();
}
