//! Integration tests over the real communication substrate: TCP store
//! rendezvous, ranktable distribution through the store, and the
//! serial-vs-parallel establishment comparison on real sockets.

use flashrecovery::comms::{establish, TcpStoreClient, TcpStoreServer};
use flashrecovery::coordinator::{RankEntry, Ranktable};
use flashrecovery::util::Json;
use std::time::Duration;

fn entry(rank: usize) -> RankEntry {
    RankEntry {
        rank,
        node: rank,
        device: 0,
        addr: format!("10.0.0.{rank}:2900"),
    }
}

#[test]
fn rendezvous_via_store_like_torchrun() {
    // master publishes the rendezvous info; workers wait on it — the
    // TCPStore pattern the paper's restart path re-establishes.
    let server = TcpStoreServer::start().unwrap();
    let addr = server.addr();

    let mut waiters = Vec::new();
    for rank in 1..4 {
        waiters.push(std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            c.hello(rank as u64).unwrap();
            let payload = c.wait("rendezvous/v1").unwrap();
            let v = Json::parse(std::str::from_utf8(&payload).unwrap()).unwrap();
            v.get("world").as_usize().unwrap()
        }));
    }
    std::thread::sleep(Duration::from_millis(30));
    let mut master = TcpStoreClient::connect(addr).unwrap();
    master.hello(0).unwrap();
    let mut info = Json::object();
    info.set("world", 4usize).set("master_addr", "127.0.0.1");
    master.set("rendezvous/v1", info.render().as_bytes()).unwrap();

    for w in waiters {
        assert_eq!(w.join().unwrap(), 4);
    }
    assert_eq!(server.hello_count(), 4);
}

#[test]
fn ranktable_distributed_through_store() {
    // The controller can also publish the ranktable via the store
    // (shared-file semantics over TCP): one set, n O(1) gets.
    let server = TcpStoreServer::start().unwrap();
    let addr = server.addr();
    let table = Ranktable::new((0..8).map(entry).collect());

    let mut c = TcpStoreClient::connect(addr).unwrap();
    c.set("ranktable", table.to_json().render().as_bytes()).unwrap();

    let mut readers = Vec::new();
    for _ in 0..8 {
        readers.push(std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            let bytes = c.get("ranktable").unwrap().unwrap();
            Ranktable::from_json(&Json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap())
                .unwrap()
        }));
    }
    for r in readers {
        let t = r.join().unwrap();
        assert_eq!(t, table);
        t.validate().unwrap();
    }
}

#[test]
fn barrier_counter_synchronizes_workers() {
    let server = TcpStoreServer::start().unwrap();
    let addr = server.addr();
    let n = 6;
    let mut handles = Vec::new();
    for _ in 0..n {
        handles.push(std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            let v = c.add("barrier/epoch0", 1).unwrap();
            // after incrementing, wait for the release key
            if v == n {
                c.set("barrier/epoch0/done", b"1").unwrap();
            }
            c.wait("barrier/epoch0/done").unwrap();
            v
        }));
    }
    let mut seen: Vec<i64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    seen.sort();
    assert_eq!(seen, (1..=n).collect::<Vec<_>>());
}

#[test]
fn parallel_establishment_not_slower_than_serial() {
    // On localhost the absolute numbers are microscopic, but the
    // parallel path must never be *slower* by more than noise, and
    // both must connect everyone.
    let server = TcpStoreServer::start().unwrap();
    let n = 64;
    let (t_serial, c1) = establish(server.addr(), n, 1).unwrap();
    let (t_par, c2) = establish(server.addr(), n, 8).unwrap();
    assert_eq!(c1.len() + c2.len(), 2 * n);
    assert_eq!(server.hello_count(), 2 * n as u64);
    assert!(
        t_par.as_secs_f64() < t_serial.as_secs_f64() * 3.0 + 0.05,
        "parallel {t_par:?} vs serial {t_serial:?}"
    );
}

#[test]
fn store_values_survive_client_churn() {
    let server = TcpStoreServer::start().unwrap();
    let addr = server.addr();
    {
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.set("persistent", b"v1").unwrap();
    } // client dropped
    let mut c2 = TcpStoreClient::connect(addr).unwrap();
    assert_eq!(c2.get("persistent").unwrap().as_deref(), Some(&b"v1"[..]));
    assert_eq!(c2.count().unwrap(), 1);
}
