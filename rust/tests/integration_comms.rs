//! Integration tests over the real communication substrate: TCP store
//! rendezvous, ranktable distribution through the store, the
//! serial-vs-parallel establishment comparison on real sockets, and
//! the scale-independence invariants of the epoch-fenced group
//! rebuild protocol.

use flashrecovery::comms::{establish, FencedWait, TcpStoreClient, TcpStoreServer};
use flashrecovery::config::ParallelismConfig;
use flashrecovery::coordinator::rendezvous::{
    rebuild_episode, topology_for, EpisodeConfig,
};
use flashrecovery::coordinator::{RankEntry, Ranktable};
use flashrecovery::util::Json;
use std::time::Duration;

fn entry(rank: usize) -> RankEntry {
    RankEntry {
        rank,
        node: rank,
        device: 0,
        addr: format!("10.0.0.{rank}:2900"),
    }
}

#[test]
fn rendezvous_via_store_like_torchrun() {
    // master publishes the rendezvous info; workers wait on it — the
    // TCPStore pattern the paper's restart path re-establishes.
    let server = TcpStoreServer::start().unwrap();
    let addr = server.addr();

    let mut waiters = Vec::new();
    for rank in 1..4 {
        waiters.push(std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            c.hello(rank as u64).unwrap();
            let payload = c.wait("rendezvous/v1").unwrap();
            let v = Json::parse(std::str::from_utf8(&payload).unwrap()).unwrap();
            v.get("world").as_usize().unwrap()
        }));
    }
    std::thread::sleep(Duration::from_millis(30));
    let mut master = TcpStoreClient::connect(addr).unwrap();
    master.hello(0).unwrap();
    let mut info = Json::object();
    info.set("world", 4usize).set("master_addr", "127.0.0.1");
    master.set("rendezvous/v1", info.render().as_bytes()).unwrap();

    for w in waiters {
        assert_eq!(w.join().unwrap(), 4);
    }
    assert_eq!(server.metrics_snapshot().counter("store.hellos"), 4);
}

#[test]
fn ranktable_distributed_through_store() {
    // The controller can also publish the ranktable via the store
    // (shared-file semantics over TCP): one set, n O(1) gets.
    let server = TcpStoreServer::start().unwrap();
    let addr = server.addr();
    let table = Ranktable::new((0..8).map(entry).collect());

    let mut c = TcpStoreClient::connect(addr).unwrap();
    c.set("ranktable", table.to_json().render().as_bytes()).unwrap();

    let mut readers = Vec::new();
    for _ in 0..8 {
        readers.push(std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            let bytes = c.get("ranktable").unwrap().unwrap();
            Ranktable::from_json(&Json::parse(std::str::from_utf8(&bytes).unwrap()).unwrap())
                .unwrap()
        }));
    }
    for r in readers {
        let t = r.join().unwrap();
        assert_eq!(t, table);
        t.validate().unwrap();
    }
}

#[test]
fn barrier_counter_synchronizes_workers() {
    let server = TcpStoreServer::start().unwrap();
    let addr = server.addr();
    let n = 6;
    let mut handles = Vec::new();
    for _ in 0..n {
        handles.push(std::thread::spawn(move || {
            let mut c = TcpStoreClient::connect(addr).unwrap();
            let v = c.add("barrier/epoch0", 1).unwrap();
            // after incrementing, wait for the release key
            if v == n {
                c.set("barrier/epoch0/done", b"1").unwrap();
            }
            c.wait("barrier/epoch0/done").unwrap();
            v
        }));
    }
    let mut seen: Vec<i64> = handles.into_iter().map(|h| h.join().unwrap()).collect();
    seen.sort();
    assert_eq!(seen, (1..=n).collect::<Vec<_>>());
}

#[test]
fn parallel_establishment_not_slower_than_serial() {
    // On localhost the absolute numbers are microscopic, but the
    // parallel path must never be *slower* by more than noise, and
    // both must connect everyone.
    let server = TcpStoreServer::start().unwrap();
    let n = 64;
    let (t_serial, c1) = establish(server.addr(), n, 1).unwrap();
    let (t_par, c2) = establish(server.addr(), n, 8).unwrap();
    assert_eq!(c1.len() + c2.len(), 2 * n);
    assert_eq!(server.metrics_snapshot().counter("store.hellos"), 2 * n as u64);
    assert!(
        t_par.as_secs_f64() < t_serial.as_secs_f64() * 3.0 + 0.05,
        "parallel {t_par:?} vs serial {t_serial:?}"
    );
}

fn sweep_table(n: usize) -> Ranktable {
    Ranktable::new((0..n).map(entry).collect())
}

#[test]
fn survivor_message_count_scale_independent_64_to_4096() {
    // The scale-independence invariant (paper §III-D): as the cluster
    // grows 64 -> 4096 ranks, the store messages each surviving node
    // spends on a rebuild stay constant — 3 (fenced delta wait, arrive
    // add, release wait) plus at most 1 for the barrier releaser. The
    // coordinator budget stays O(replacements), and total store
    // traffic tracks live participants, never world size.
    let live = 8; // fixed live-agent sample at every scale
    let mut budgets: Vec<u64> = Vec::new();
    let mut totals: Vec<u64> = Vec::new();
    for n in [64usize, 256, 1024, 4096] {
        let par = topology_for(n);
        assert_eq!(par.world_size(), n);
        let server = TcpStoreServer::start().unwrap();
        let table = sweep_table(n);
        let failed = [1usize];
        let replacement = RankEntry {
            rank: 1,
            node: n + 1,
            device: 0,
            addr: "10.200.0.1:2900".to_string(),
        };
        let before = server.metrics_snapshot().counter("store.requests");
        let out = rebuild_episode(
            &server.endpoints(),
            &table,
            &par,
            &failed,
            &[replacement],
            0,
            &EpisodeConfig { live_survivors: live, ..Default::default() },
        )
        .unwrap();
        assert_eq!(out.live_survivors, live);
        budgets.push(out.survivor_ops_max);
        assert_eq!(out.coordinator_ops, 1 + 4, "coordinator O(k) at n={n}");
        assert_eq!(out.replacement_ops_max, 6, "replacement O(1) at n={n}");
        totals.push(server.metrics_snapshot().counter("store.requests") - before);
    }
    assert!(
        budgets.windows(2).all(|w| w[0] == w[1]),
        "survivor message count must not scale with the cluster: {budgets:?}"
    );
    assert_eq!(budgets[0], 3, "budget is exactly 3: {budgets:?}");
    // total store traffic is bounded by participants, not world size:
    // with an identical live-agent sample the per-episode request
    // count is deterministic, so n=64 and n=4096 must match exactly
    let (lo, hi) = (
        *totals.iter().min().unwrap(),
        *totals.iter().max().unwrap(),
    );
    assert_eq!(
        lo, hi,
        "store traffic must not grow with cluster size: {totals:?}"
    );
}

#[test]
fn rebuild_epoch_bump_releases_stale_waiter_during_churn() {
    // Live-recovery gap behind `server_shutdown_releases_waiters`: a
    // client parked on a *previous* epoch's key while the server
    // churns through rebuilds must come back with a retryable
    // `Superseded` outcome — not hang until its 300s read timeout —
    // and succeed on the retry at the new epoch.
    let cfg = ParallelismConfig::dp(4);
    let server = TcpStoreServer::start().unwrap();
    let addr = server.addr();

    let stale = std::thread::spawn(move || {
        let mut c = TcpStoreClient::connect(addr).unwrap();
        // parked at epoch 1 on a key that epoch never publishes (e.g.
        // a join the failed node will never send)
        let current = match c.wait_epoch("rdzv/1/join/99", 1).unwrap() {
            FencedWait::Superseded { current } => current,
            other => panic!("expected stale waiter superseded, got {other:?}"),
        };
        // retry at the epoch the fence reported: must resolve
        c.wait_epoch(&format!("rdzv/{current}/delta"), current).unwrap()
    });
    std::thread::sleep(Duration::from_millis(60));

    // two back-to-back rebuild episodes (server churn): epoch 1's keys
    // are consumed and epoch 2 supersedes the stale waiter
    let mut table = sweep_table(4);
    let mut epoch = 0;
    for tag in 0..2u64 {
        let replacement = RankEntry {
            rank: 2,
            node: 100 + tag as usize,
            device: 0,
            addr: format!("10.9.{tag}.2:2900"),
        };
        let out = rebuild_episode(
            &server.endpoints(),
            &table,
            &cfg,
            &[2],
            &[replacement],
            epoch,
            &EpisodeConfig { live_survivors: 4, ..Default::default() },
        )
        .unwrap();
        epoch = out.epoch;
        table = out.table;
    }
    assert_eq!(epoch, 2);
    let released = stale.join().unwrap();
    assert!(
        matches!(released, FencedWait::Value(_)),
        "retry at the fenced epoch must see that epoch's delta: {released:?}"
    );
}

#[test]
fn store_values_survive_client_churn() {
    let server = TcpStoreServer::start().unwrap();
    let addr = server.addr();
    {
        let mut c = TcpStoreClient::connect(addr).unwrap();
        c.set("persistent", b"v1").unwrap();
    } // client dropped
    let mut c2 = TcpStoreClient::connect(addr).unwrap();
    assert_eq!(c2.get("persistent").unwrap().as_deref(), Some(&b"v1"[..]));
    assert_eq!(c2.count().unwrap(), 1);
}
