//! Integration tests for the checkpoint-free restore wire protocol
//! (DESIGN.md §9): shard-aware streaming restore over real TCP
//! sockets, source discovery through the epoch-fenced store, and
//! failure-during-restore abort semantics.
//!
//! These run against synthetic snapshots (the `Snapshot` container is
//! plain host memory), so the full protocol — planner, store
//! advertise/claim, chunked checksummed streams, epoch fencing —
//! exercises on every offline CI run with no xla plane required.

use flashrecovery::checkpoint::Snapshot;
use flashrecovery::comms::state_stream::{EpochFence, RestoreError, StreamConfig};
use flashrecovery::comms::tcp_store::TcpStoreServer;
use flashrecovery::config::ParallelismConfig;
use flashrecovery::coordinator::restore::{
    bump_epoch, plan_shard_restore, restore_episode, synthetic_snapshot,
};
use std::collections::BTreeMap;
use std::time::{Duration, Instant};

fn replica_states(ranks: &[usize], step: u64, elems: usize) -> BTreeMap<usize, Snapshot> {
    ranks
        .iter()
        .map(|&r| (r, synthetic_snapshot(step, elems)))
        .collect()
}

#[test]
fn one_rank_killed_per_zero_shard_group_restores_from_distinct_replicas() {
    // The acceptance scenario: dp=8 sharded 4 ways -> four shard
    // groups of two replicas each. Kill one rank per group; every lost
    // shard must be restored from the surviving replica of the *same*
    // group — four distinct sources, four parallel socket transfers —
    // and every DP-group member must be byte-identical afterwards.
    let par = ParallelismConfig::dp(8).with_zero(4);
    let lost = vec![0usize, 1, 2, 3];
    let survivors: Vec<usize> = (4..8).collect();
    let step = 11;
    let survivor_steps: Vec<(usize, u64)> =
        survivors.iter().map(|&r| (r, step)).collect();

    let plan = plan_shard_restore(&par, &survivor_steps, &lost);
    assert!(plan.replica_feasible());
    assert_eq!(plan.transfers.len(), 4, "one parallel transfer per lost shard");

    let states = replica_states(&survivors, step, 12_000);
    let server = TcpStoreServer::start().unwrap();
    let fence = EpochFence::new(1);
    let out = restore_episode(
        server.addr(),
        &plan,
        &states,
        1,
        &fence,
        &StreamConfig::default(),
    )
    .unwrap();

    // each lost shard came from a distinct surviving replica of the
    // same shard group
    let mut sources: Vec<usize> = out.transfers.iter().map(|t| t.source).collect();
    sources.sort_unstable();
    assert_eq!(sources, survivors, "distinct replica per lost shard");
    for t in &out.transfers {
        assert_eq!(par.shard_id(t.source), t.shard);
        assert_eq!(par.shard_id(t.target), t.shard);
        assert!(t.bytes > 0);
    }

    // byte-identical state across the whole DP group afterwards — the
    // param_hash parity the paper's module 3 promises
    let reference = states[&4].content_hash();
    assert_eq!(out.restored.len(), 4);
    for (&rank, snap) in &out.restored {
        assert_eq!(snap.step, step, "rank {rank} resumed at the wrong step");
        assert_eq!(
            snap.content_hash(),
            reference,
            "rank {rank} is not a bit-exact replica after restore"
        );
    }
}

#[test]
fn laggards_and_replacements_restore_in_one_episode() {
    // Mixed episode: rank 0 died, rank 2 parked one step behind the
    // resume point. Both stream from the up-to-date survivors, spread
    // across distinct sources.
    let par = ParallelismConfig::dp(4);
    let plan = plan_shard_restore(&par, &[(1, 7), (2, 6), (3, 7)], &[0]);
    assert_eq!(plan.resume_step, 7);
    assert_eq!(plan.targets(), vec![0, 2]);

    let mut states = replica_states(&[1, 3], 7, 6_000);
    states.insert(2, synthetic_snapshot(6, 6_000)); // the laggard
    let server = TcpStoreServer::start().unwrap();
    let fence = EpochFence::new(1);
    let out = restore_episode(
        server.addr(),
        &plan,
        &states,
        1,
        &fence,
        &StreamConfig::default(),
    )
    .unwrap();
    assert_eq!(out.restored.len(), 2);
    let reference = states[&1].content_hash();
    for snap in out.restored.values() {
        assert_eq!(snap.step, 7);
        assert_eq!(snap.content_hash(), reference);
    }
    let sources: Vec<usize> = out.transfers.iter().map(|t| t.source).collect();
    assert!(sources.contains(&1) && sources.contains(&3), "{sources:?}");
}

#[test]
fn mid_restore_epoch_bump_aborts_retryably_then_retry_converges() {
    // The failure-during-recovery contract end to end: a restore is in
    // flight (throttled chunks over real sockets) when the epoch is
    // bumped — every transfer must abort with a *retryable* outcome
    // promptly (no hang, no torn state), and the retried episode at
    // the new epoch must converge.
    let par = ParallelismConfig::dp(4);
    let lost = vec![0usize];
    let survivor_steps = vec![(1usize, 5u64), (2, 5), (3, 5)];
    let plan = plan_shard_restore(&par, &survivor_steps, &lost);
    let states = replica_states(&[1, 2, 3], 5, 40_000);

    let server = TcpStoreServer::start().unwrap();
    let addr = server.addr();
    let fence = EpochFence::new(1);

    let watcher_fence = fence.clone();
    let watcher = std::thread::spawn(move || {
        std::thread::sleep(Duration::from_millis(25));
        bump_epoch(addr, &watcher_fence, 2).unwrap()
    });

    // ~40 chunks x 10ms of mandatory throttle sleeps (>= ~400ms) vs a
    // 25ms bump: the abort deterministically lands mid-transfer even
    // on a loaded machine.
    let throttled = StreamConfig {
        chunk_bytes: 4 * 1024,
        throttle: Some(Duration::from_millis(10)),
        ..Default::default()
    };
    let t0 = Instant::now();
    let err = restore_episode(addr, &plan, &states, 1, &fence, &throttled)
        .expect_err("epoch bump must abort the in-flight episode");
    assert_eq!(watcher.join().unwrap(), 2);
    match err {
        RestoreError::Superseded { current } => assert_eq!(current, 2),
        RestoreError::Fatal(e) => panic!("abort must be retryable, got: {e:#}"),
    }
    assert!(
        t0.elapsed() < Duration::from_secs(30),
        "abort must be prompt, never a hang"
    );

    // retry at the new epoch: clean convergence
    let out = restore_episode(addr, &plan, &states, 2, &fence, &StreamConfig::default())
        .expect("retry at the bumped epoch must converge");
    assert_eq!(out.restored.len(), 1);
    assert_eq!(
        out.restored[&0].content_hash(),
        states[&1].content_hash()
    );
}

#[test]
fn claim_blocked_on_dead_source_is_released_by_epoch_bump() {
    // A target whose source died before advertising must not hang on
    // the store: the epoch bump releases the claim retryably. Driven
    // at the episode level by pointing the plan at a source with no
    // state-serving thread (we simulate by bumping before any
    // advertisement can matter).
    let server = TcpStoreServer::start().unwrap();
    let addr = server.addr();
    let mut client =
        flashrecovery::comms::tcp_store::TcpStoreClient::connect(addr).unwrap();
    let claimer = std::thread::spawn(move || {
        let mut c =
            flashrecovery::comms::tcp_store::TcpStoreClient::connect(addr).unwrap();
        let t0 = Instant::now();
        let out = c.claim_restore(1, 0x7777).unwrap();
        (out, t0.elapsed())
    });
    std::thread::sleep(Duration::from_millis(50));
    client.advance_epoch(2).unwrap();
    let (out, waited) = claimer.join().unwrap();
    assert_eq!(
        out,
        flashrecovery::comms::tcp_store::FencedWait::Superseded { current: 2 }
    );
    assert!(waited < Duration::from_secs(30));
}

#[test]
fn unsourced_shard_demands_checkpoint_fallback() {
    // Pure FSDP: the lost shard has no replica anywhere. The planner
    // must say so (can_recover == false) rather than serving stale or
    // wrong-shard state.
    let par = ParallelismConfig::dp(4).with_zero(4);
    assert!(!par.can_recover(&[1]));
    let plan = plan_shard_restore(&par, &[(0, 3), (2, 3), (3, 3)], &[1]);
    assert!(!plan.replica_feasible());
    assert_eq!(plan.unsourced, vec![par.shard_id(1)]);
}
