"""Layer-2 JAX model: decoder-only transformer trained by FlashRecovery.

Everything here is *build-time only*: `aot.py` lowers these functions to
HLO text once, and the Rust coordinator executes the artifacts via PJRT
for every training step. Nothing in `python/` runs on the request path.

Interop contract with Rust (see rust/src/runtime/manifest.rs):

* Parameters are a flat *list* of f32 arrays in the canonical order
  produced by `param_specs(cfg)`. Rust holds them as `xla::Literal`s and
  passes them positionally.
* `fwd_bwd`:   (*params, tokens)                  -> (loss, *grads)
* `opt_step`:  (*params, *m, *v, step, *grads)    -> (*params', *m', *v')
* `train_step`: fused single-device step,
               (*params, *m, *v, step, tokens)    -> (loss, *params', *m', *v')
* `init`:      (seed,)                            -> (*params,)
* `tokens` is i32[batch, seq+1]; inputs = tokens[:, :-1], targets =
  tokens[:, 1:]. `step` is f32[] (Adam bias correction), 1-based.

Splitting fwd_bwd from opt_step is deliberate: the Rust-side gradient
allreduce between them is the paper's synchronisation barrier (§III-E,
Fig. 7) that the step-tag protocol brackets.
"""

import dataclasses
from typing import List, Tuple

import jax
import jax.numpy as jnp

from compile.kernels.attention import flash_attention


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """Decoder-only transformer hyperparameters."""
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    d_ff: int
    vocab: int
    seq: int
    batch: int  # per-DP-rank micro-batch lowered into the artifact

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads


# The three sizes referenced throughout DESIGN.md. `base` is the ~100M
# end-to-end config; `tiny`/`small` keep tests and benches fast.
MODEL_SIZES = {
    "tiny": ModelConfig("tiny", n_layers=2, d_model=64, n_heads=2, d_ff=256,
                        vocab=256, seq=32, batch=4),
    "small": ModelConfig("small", n_layers=4, d_model=256, n_heads=4,
                         d_ff=1024, vocab=2048, seq=64, batch=4),
    "base": ModelConfig("base", n_layers=12, d_model=768, n_heads=12,
                        d_ff=3072, vocab=8192, seq=128, batch=1),
}


@dataclasses.dataclass(frozen=True)
class AdamConfig:
    lr: float = 1e-3
    beta1: float = 0.9
    beta2: float = 0.999
    eps: float = 1e-8
    grad_clip: float = 1.0  # global-norm clip; 0 disables


def param_specs(cfg: ModelConfig) -> List[Tuple[str, Tuple[int, ...]]]:
    """Canonical (name, shape) list — the Rust interop ordering."""
    specs = [
        ("embed", (cfg.vocab, cfg.d_model)),
        ("pos", (cfg.seq, cfg.d_model)),
    ]
    for i in range(cfg.n_layers):
        p = f"layer{i}."
        specs += [
            (p + "ln1", (cfg.d_model,)),
            (p + "wq", (cfg.d_model, cfg.d_model)),
            (p + "wk", (cfg.d_model, cfg.d_model)),
            (p + "wv", (cfg.d_model, cfg.d_model)),
            (p + "wo", (cfg.d_model, cfg.d_model)),
            (p + "ln2", (cfg.d_model,)),
            (p + "w1", (cfg.d_model, cfg.d_ff)),
            (p + "w2", (cfg.d_ff, cfg.d_model)),
        ]
    specs.append(("ln_f", (cfg.d_model,)))
    return specs


def param_count(cfg: ModelConfig) -> int:
    return sum(int(jnp.prod(jnp.array(s))) for _, s in param_specs(cfg))


def init_params(cfg: ModelConfig, seed) -> List[jax.Array]:
    """Initialise parameters from an i32 seed scalar (lowered to HLO)."""
    key = jax.random.PRNGKey(seed)
    params = []
    for i, (name, shape) in enumerate(param_specs(cfg)):
        sub = jax.random.fold_in(key, i)
        if name.endswith(("ln1", "ln2", "ln_f")):
            params.append(jnp.ones(shape, jnp.float32))
        elif name == "pos":
            params.append(
                0.01 * jax.random.normal(sub, shape, dtype=jnp.float32))
        else:
            fan_in = shape[0]
            std = fan_in ** -0.5
            # Scale residual-output projections down by sqrt(2*L) (GPT-2).
            if name.endswith(("wo", "w2")):
                std /= (2.0 * cfg.n_layers) ** 0.5
            params.append(
                std * jax.random.normal(sub, shape, dtype=jnp.float32))
    return params


def _rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return x * jax.lax.rsqrt(var + eps) * scale


def forward(cfg: ModelConfig, params: List[jax.Array], inputs) -> jax.Array:
    """Token logits. inputs: i32[batch, seq] -> f32[batch, seq, vocab]."""
    names = [n for n, _ in param_specs(cfg)]
    p = dict(zip(names, params))
    B, S = inputs.shape
    x = p["embed"][inputs] + p["pos"][None, :S, :]

    for i in range(cfg.n_layers):
        pre = f"layer{i}."
        h = _rms_norm(x, p[pre + "ln1"])
        q = (h @ p[pre + "wq"]).reshape(B, S, cfg.n_heads, cfg.d_head)
        k = (h @ p[pre + "wk"]).reshape(B, S, cfg.n_heads, cfg.d_head)
        v = (h @ p[pre + "wv"]).reshape(B, S, cfg.n_heads, cfg.d_head)
        q, k, v = (t.transpose(0, 2, 1, 3) for t in (q, k, v))
        attn = flash_attention(q, k, v, causal=True)
        attn = attn.transpose(0, 2, 1, 3).reshape(B, S, cfg.d_model)
        x = x + attn @ p[pre + "wo"]

        h = _rms_norm(x, p[pre + "ln2"])
        h = jax.nn.gelu(h @ p[pre + "w1"])
        x = x + h @ p[pre + "w2"]

    x = _rms_norm(x, p["ln_f"])
    # Tied unembedding: logits via the embedding matrix.
    return x @ p["embed"].T


def loss_fn(cfg: ModelConfig, params: List[jax.Array], tokens) -> jax.Array:
    """Mean causal-LM cross-entropy. tokens: i32[batch, seq+1]."""
    inputs, targets = tokens[:, :-1], tokens[:, 1:]
    logits = forward(cfg, params, inputs)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)


def fwd_bwd(cfg: ModelConfig, params: List[jax.Array], tokens):
    """(loss, grads) for one micro-batch — the pre-barrier phase."""
    loss, grads = jax.value_and_grad(
        lambda ps: loss_fn(cfg, ps, tokens))(params)
    return loss, grads


def adam_step(cfg: ModelConfig, opt: AdamConfig, params, m, v, step, grads):
    """One Adam update — the post-barrier phase.

    `step` is a 1-based f32 scalar; grads are the *already allreduced*
    gradients handed back by the Rust coordinator.
    """
    if opt.grad_clip > 0:
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g)) for g in grads))
        scale = jnp.minimum(1.0, opt.grad_clip / (gnorm + 1e-12))
        grads = [g * scale for g in grads]
    b1, b2 = opt.beta1, opt.beta2
    bc1 = 1.0 - b1 ** step
    bc2 = 1.0 - b2 ** step
    new_p, new_m, new_v = [], [], []
    for pi, mi, vi, gi in zip(params, m, v, grads):
        mi = b1 * mi + (1.0 - b1) * gi
        vi = b2 * vi + (1.0 - b2) * jnp.square(gi)
        update = opt.lr * (mi / bc1) / (jnp.sqrt(vi / bc2) + opt.eps)
        new_p.append(pi - update)
        new_m.append(mi)
        new_v.append(vi)
    return new_p, new_m, new_v


def train_step(cfg: ModelConfig, opt: AdamConfig, params, m, v, step, tokens):
    """Fused single-device step (quickstart / throughput reference)."""
    loss, grads = fwd_bwd(cfg, params, tokens)
    new_p, new_m, new_v = adam_step(cfg, opt, params, m, v, step, grads)
    return loss, new_p, new_m, new_v
