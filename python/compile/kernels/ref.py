"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth: deliberately simple, O(L^2)
materialising implementations with no tiling tricks. pytest (and the
hypothesis sweeps in python/tests) assert that the Pallas kernels in
`attention.py` match these to tight tolerances, for both the forward
pass and the gradients (via jax.grad through `mha_ref`).
"""

import jax
import jax.numpy as jnp

NEG_INF = -1e30  # large-negative instead of -inf: keeps softmax NaN-free


def mha_ref(q, k, v, *, causal=True, scale=None):
    """Multi-head attention reference.

    Args:
      q, k, v: f32[batch, heads, seq, d_head]
      causal:  apply a causal (lower-triangular) mask.
      scale:   logit scale; defaults to 1/sqrt(d_head).

    Returns:
      f32[batch, heads, seq, d_head]
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.array(q.shape[-1], dtype=q.dtype))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        seq_q, seq_k = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((seq_q, seq_k), dtype=bool))
        logits = jnp.where(mask, logits, NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", probs, v)


def mha_ref_lse(q, k, v, *, causal=True, scale=None):
    """Reference attention that also returns the per-row logsumexp.

    Used to validate the auxiliary LSE output the Pallas forward saves
    for the backward pass.
    """
    if scale is None:
        scale = 1.0 / jnp.sqrt(jnp.array(q.shape[-1], dtype=q.dtype))
    logits = jnp.einsum("bhqd,bhkd->bhqk", q, k) * scale
    if causal:
        seq_q, seq_k = logits.shape[-2], logits.shape[-1]
        mask = jnp.tril(jnp.ones((seq_q, seq_k), dtype=bool))
        logits = jnp.where(mask, logits, NEG_INF)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    probs = jnp.exp(logits - lse[..., None])
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v)
    return out, lse
