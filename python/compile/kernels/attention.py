"""Tiled causal flash-attention as a Pallas kernel (forward + backward).

This is the Layer-1 compute hot-spot of the reproduction: the attention
inner loop of the transformer the FlashRecovery coordinator trains. It
follows the FlashAttention structure re-thought for TPU (see DESIGN.md
§Hardware-Adaptation):

* the grid iterates over (batch*heads, query blocks); each grid cell
  holds one Q tile in VMEM and *streams* K/V tiles HBM→VMEM with an
  online-softmax carry (running max `m`, running sum `l`, accumulator),
  the TPU analogue of the CUDA version's shared-memory staging;
* tile shapes come from BlockSpec and are sized for the ~16 MiB VMEM
  budget (see `vmem_bytes`), with MXU-friendly inner matmuls;
* the backward pass recomputes attention probabilities block-wise (no
  O(L^2) residuals): one kernel accumulates dQ over K blocks, a second
  accumulates dK/dV over Q blocks, both using the saved row-wise
  logsumexp and the precomputed `delta = rowsum(dO * O)`.

Kernels are lowered with ``interpret=True`` so they become plain HLO and
run on the CPU PJRT plugin (real-TPU lowering emits a Mosaic custom-call
the CPU client cannot execute). Correctness is pinned to
``kernels.ref`` by pytest + hypothesis sweeps in ``python/tests``.
"""

import functools
import os

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30

# interpret=True is required for CPU-PJRT execution (see module docstring).
INTERPRET = os.environ.get("FLASHREC_PALLAS_INTERPRET", "1") != "0"


def pick_block(seq_len: int, preferred: int = 128) -> int:
    """Largest power-of-two block size <= `preferred` dividing `seq_len`."""
    b = preferred
    while b > 1 and seq_len % b != 0:
        b //= 2
    return max(b, 1)


def vmem_bytes(block_q: int, block_k: int, d_head: int) -> int:
    """Estimated VMEM working set of one forward grid cell, in bytes.

    Q tile + one K tile + one V tile + accumulator + (m, l) carries +
    logits tile, all f32. Used by DESIGN.md §Perf and the kernel-shape
    tests to keep tiles inside the 16 MiB/core VMEM budget.
    """
    f32 = 4
    q = block_q * d_head
    kv = 2 * block_k * d_head
    acc = block_q * d_head
    carries = 2 * block_q
    logits = block_q * block_k
    return f32 * (q + kv + acc + carries + logits)


# ---------------------------------------------------------------------------
# Forward kernel
# ---------------------------------------------------------------------------


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, scale, causal,
                block_q, block_k, seq_len):
    iq = pl.program_id(1)
    d_head = q_ref.shape[-1]
    q = q_ref[0, :, :] * scale  # (block_q, d)

    n_k_total = seq_len // block_k
    if causal:
        # Highest K block that intersects rows [iq*bq, (iq+1)*bq): the
        # streaming loop skips fully-masked blocks entirely.
        n_k = ((iq + 1) * block_q + block_k - 1) // block_k
    else:
        n_k = n_k_total

    row_ids = iq * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(ik, carry):
        m_prev, l_prev, acc_prev = carry
        k = pl.load(k_ref, (0, pl.dslice(ik * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(ik * block_k, block_k), slice(None)))
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)
        if causal:
            col_ids = ik * block_k + lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(col_ids <= row_ids, s, NEG_INF)
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_new = l_prev * alpha + jnp.sum(p, axis=-1)
        acc_new = acc_prev * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return m_new, l_new, acc_new

    init = (
        jnp.full((block_q,), NEG_INF, dtype=jnp.float32),
        jnp.zeros((block_q,), dtype=jnp.float32),
        jnp.zeros((block_q, d_head), dtype=jnp.float32),
    )
    m, l, acc = lax.fori_loop(0, n_k, body, init)
    o_ref[0, :, :] = acc / l[:, None]
    lse_ref[0, :] = m + jnp.log(l)


def _fwd(q, k, v, scale, causal, block_q, block_k, interpret):
    batch, heads, seq_len, d_head = q.shape
    bh = batch * heads
    q3 = q.reshape(bh, seq_len, d_head)
    k3 = k.reshape(bh, seq_len, d_head)
    v3 = v.reshape(bh, seq_len, d_head)

    grid = (bh, seq_len // block_q)
    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, block_q=block_q,
        block_k=block_k, seq_len=seq_len)
    o3, lse3 = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d_head), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq_len, d_head), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, seq_len, d_head), lambda b, i: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d_head), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seq_len, d_head), jnp.float32),
            jax.ShapeDtypeStruct((bh, seq_len), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3)
    out = o3.reshape(batch, heads, seq_len, d_head)
    lse = lse3.reshape(batch, heads, seq_len)
    return out, lse


# ---------------------------------------------------------------------------
# Backward kernels
# ---------------------------------------------------------------------------


def _dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref, *,
               scale, causal, block_q, block_k, seq_len):
    iq = pl.program_id(1)
    d_head = q_ref.shape[-1]
    q = q_ref[0, :, :]
    do = do_ref[0, :, :]
    lse = lse_ref[0, :]
    delta = delta_ref[0, :]

    if causal:
        n_k = ((iq + 1) * block_q + block_k - 1) // block_k
    else:
        n_k = seq_len // block_k
    row_ids = iq * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)

    def body(ik, dq):
        k = pl.load(k_ref, (0, pl.dslice(ik * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (0, pl.dslice(ik * block_k, block_k), slice(None)))
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            col_ids = ik * block_k + lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(col_ids <= row_ids, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        return dq + jnp.dot(ds, k, preferred_element_type=jnp.float32) * scale

    dq = lax.fori_loop(0, n_k, body,
                       jnp.zeros((block_q, d_head), dtype=jnp.float32))
    dq_ref[0, :, :] = dq


def _dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                dk_ref, dv_ref, *, scale, causal, block_q, block_k, seq_len):
    ik = pl.program_id(1)
    d_head = q_ref.shape[-1]
    k = k_ref[0, :, :]
    v = v_ref[0, :, :]

    n_q_total = seq_len // block_q
    if causal:
        # Lowest Q block whose rows can see columns [ik*bk, (ik+1)*bk).
        start_q = (ik * block_k) // block_q
    else:
        start_q = 0
    col_ids = ik * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    def body(iq, carry):
        dk, dv = carry
        q = pl.load(q_ref, (0, pl.dslice(iq * block_q, block_q), slice(None)))
        do = pl.load(do_ref, (0, pl.dslice(iq * block_q, block_q), slice(None)))
        lse = pl.load(lse_ref, (0, pl.dslice(iq * block_q, block_q)))
        delta = pl.load(delta_ref, (0, pl.dslice(iq * block_q, block_q)))
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            row_ids = iq * block_q + lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            s = jnp.where(col_ids <= row_ids, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # (bq, bk)
        dv_new = dv + jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dk_new = dk + jnp.dot(ds.T, q, preferred_element_type=jnp.float32) * scale
        return dk_new, dv_new

    init = (jnp.zeros((block_k, d_head), dtype=jnp.float32),
            jnp.zeros((block_k, d_head), dtype=jnp.float32))
    dk, dv = lax.fori_loop(start_q, n_q_total, body, init)
    dk_ref[0, :, :] = dk
    dv_ref[0, :, :] = dv


def _bwd_impl(q, k, v, o, lse, do, scale, causal, block_q, block_k, interpret):
    batch, heads, seq_len, d_head = q.shape
    bh = batch * heads
    delta = jnp.sum(do * o, axis=-1)  # (B, H, S)

    q3 = q.reshape(bh, seq_len, d_head)
    k3 = k.reshape(bh, seq_len, d_head)
    v3 = v.reshape(bh, seq_len, d_head)
    do3 = do.reshape(bh, seq_len, d_head)
    lse3 = lse.reshape(bh, seq_len)
    delta3 = delta.reshape(bh, seq_len)

    full = lambda b, i: (b, 0, 0)
    full2 = lambda b, i: (b, 0)

    dq3 = pl.pallas_call(
        functools.partial(_dq_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, seq_len=seq_len),
        grid=(bh, seq_len // block_q),
        in_specs=[
            pl.BlockSpec((1, block_q, d_head), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq_len, d_head), full),
            pl.BlockSpec((1, seq_len, d_head), full),
            pl.BlockSpec((1, block_q, d_head), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
            pl.BlockSpec((1, block_q), lambda b, i: (b, i)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d_head), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, seq_len, d_head), jnp.float32),
        interpret=interpret,
    )(q3, k3, v3, do3, lse3, delta3)

    dk3, dv3 = pl.pallas_call(
        functools.partial(_dkv_kernel, scale=scale, causal=causal,
                          block_q=block_q, block_k=block_k, seq_len=seq_len),
        grid=(bh, seq_len // block_k),
        in_specs=[
            pl.BlockSpec((1, seq_len, d_head), full),
            pl.BlockSpec((1, block_k, d_head), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_k, d_head), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, seq_len, d_head), full),
            pl.BlockSpec((1, seq_len), full2),
            pl.BlockSpec((1, seq_len), full2),
        ],
        out_specs=[
            pl.BlockSpec((1, block_k, d_head), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, block_k, d_head), lambda b, i: (b, i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, seq_len, d_head), jnp.float32),
            jax.ShapeDtypeStruct((bh, seq_len, d_head), jnp.float32),
        ],
        interpret=interpret,
    )(q3, k3, v3, do3, lse3, delta3)

    dq = dq3.reshape(batch, heads, seq_len, d_head)
    dk = dk3.reshape(batch, heads, seq_len, d_head)
    dv = dv3.reshape(batch, heads, seq_len, d_head)
    return dq, dk, dv


# ---------------------------------------------------------------------------
# custom_vjp wiring + public API
# ---------------------------------------------------------------------------


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def _flash_attention(q, k, v, scale, causal, block_q, block_k, interpret):
    out, _ = _fwd(q, k, v, scale, causal, block_q, block_k, interpret)
    return out


def _fa_fwd(q, k, v, scale, causal, block_q, block_k, interpret):
    out, lse = _fwd(q, k, v, scale, causal, block_q, block_k, interpret)
    return out, (q, k, v, out, lse)


def _fa_bwd(scale, causal, block_q, block_k, interpret, res, do):
    q, k, v, o, lse = res
    return _bwd_impl(q, k, v, o, lse, do, scale, causal, block_q, block_k,
                     interpret)


_flash_attention.defvjp(_fa_fwd, _fa_bwd)


def flash_attention(q, k, v, *, causal=True, scale=None, block_q=None,
                    block_k=None, interpret=None):
    """Tiled (flash) multi-head attention.

    Drop-in replacement for ``ref.mha_ref`` with O(seq) memory per grid
    cell. Differentiable via a custom VJP whose backward pass is also a
    pair of Pallas kernels.

    Args:
      q, k, v: f32[batch, heads, seq, d_head]; seq must be divisible by
        the chosen block sizes.
      causal: apply causal masking (fully-masked K/V blocks are skipped,
        not just masked).
      scale: logit scale, default 1/sqrt(d_head).
      block_q, block_k: tile sizes; default the largest power of two
        <= 128 dividing seq.
      interpret: override the module-level INTERPRET flag.
    """
    batch, heads, seq_len, d_head = q.shape
    if scale is None:
        scale = float(1.0 / (d_head ** 0.5))
    if block_q is None:
        block_q = pick_block(seq_len)
    if block_k is None:
        block_k = pick_block(seq_len)
    if interpret is None:
        interpret = INTERPRET
    if seq_len % block_q or seq_len % block_k:
        raise ValueError(
            f"seq_len={seq_len} not divisible by blocks ({block_q},{block_k})")
    return _flash_attention(q, k, v, scale, causal, block_q, block_k,
                            interpret)
