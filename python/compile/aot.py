"""AOT lowering driver: JAX model -> HLO text artifacts + manifest.

Run once at build time (`make artifacts`). Emits, per model size:

    artifacts/init_<size>.hlo.txt        (seed,)                    -> (*params,)
    artifacts/fwd_bwd_<size>.hlo.txt     (*params, tokens)          -> (loss, *grads)
    artifacts/opt_step_<size>.hlo.txt    (*params,*m,*v,step,*grads)-> (*p',*m',*v')
    artifacts/train_step_<size>.hlo.txt  (*params,*m,*v,step,tokens)-> (loss,*p',*m',*v')
    artifacts/manifest.json              interop contract for Rust

HLO *text* is the interchange format, NOT serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version behind the `xla` crate) rejects (`proto.id() <=
INT_MAX`); the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/load_hlo.
"""

import argparse
import json
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned by parser)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _shape_entry(shape, dtype):
    return {"shape": list(shape), "dtype": dtype}


def lower_size(cfg: M.ModelConfig, opt: M.AdamConfig, out_dir: str) -> dict:
    """Lower all four artifacts for one model size; return manifest entry."""
    specs = M.param_specs(cfg)
    p_specs = [_spec(s) for _, s in specs]
    tokens_spec = _spec((cfg.batch, cfg.seq + 1), jnp.int32)
    step_spec = _spec((), jnp.float32)
    seed_spec = _spec((), jnp.int32)

    def init_fn(seed):
        return tuple(M.init_params(cfg, seed))

    def fwd_bwd_fn(params, tokens):
        loss, grads = M.fwd_bwd(cfg, list(params), tokens)
        return (loss, *grads)

    def opt_step_fn(params, m, v, step, grads):
        new_p, new_m, new_v = M.adam_step(
            cfg, opt, list(params), list(m), list(v), step, list(grads))
        return (*new_p, *new_m, *new_v)

    def train_step_fn(params, m, v, step, tokens):
        loss, new_p, new_m, new_v = M.train_step(
            cfg, opt, list(params), list(m), list(v), step, tokens)
        return (loss, *new_p, *new_m, *new_v)

    artifacts = {}

    def emit(name, fn, *arg_specs):
        lowered = jax.jit(fn).lower(*arg_specs)
        text = to_hlo_text(lowered)
        fname = f"{name}_{cfg.name}.hlo.txt"
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        print(f"  wrote {fname} ({len(text) / 1e6:.2f} MB)", flush=True)
        artifacts[name] = {"file": fname}

    print(f"[aot] lowering size={cfg.name} "
          f"(params={M.param_count(cfg) / 1e6:.2f}M)", flush=True)
    emit("init", init_fn, seed_spec)
    emit("fwd_bwd", fwd_bwd_fn, tuple(p_specs), tokens_spec)
    emit("opt_step", opt_step_fn, tuple(p_specs), tuple(p_specs),
         tuple(p_specs), step_spec, tuple(p_specs))
    emit("train_step", train_step_fn, tuple(p_specs), tuple(p_specs),
         tuple(p_specs), step_spec, tokens_spec)

    return {
        "config": {
            "name": cfg.name, "n_layers": cfg.n_layers,
            "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "d_ff": cfg.d_ff, "vocab": cfg.vocab, "seq": cfg.seq,
            "batch": cfg.batch, "param_count": M.param_count(cfg),
        },
        "optimizer": {
            "lr": opt.lr, "beta1": opt.beta1, "beta2": opt.beta2,
            "eps": opt.eps, "grad_clip": opt.grad_clip,
        },
        "params": [
            {"name": n, **_shape_entry(s, "f32")} for n, s in specs
        ],
        "tokens": _shape_entry((cfg.batch, cfg.seq + 1), "i32"),
        "artifacts": artifacts,
    }


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--sizes", default="tiny,small",
                    help="comma-separated subset of " +
                         ",".join(M.MODEL_SIZES))
    args = ap.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    sizes = [s.strip() for s in args.sizes.split(",") if s.strip()]
    for s in sizes:
        if s not in M.MODEL_SIZES:
            sys.exit(f"unknown size {s!r}; known: {list(M.MODEL_SIZES)}")

    manifest_path = os.path.join(args.out_dir, "manifest.json")
    manifest = {"format": 1, "models": {}}
    if os.path.exists(manifest_path):
        try:
            with open(manifest_path) as f:
                manifest = json.load(f)
        except json.JSONDecodeError:
            pass

    opt = M.AdamConfig()
    for s in sizes:
        manifest["models"][s] = lower_size(M.MODEL_SIZES[s], opt, args.out_dir)

    with open(manifest_path, "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"[aot] wrote {manifest_path}")


if __name__ == "__main__":
    main()
