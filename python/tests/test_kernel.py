"""Pallas flash-attention kernel vs the pure-jnp oracle (kernels.ref).

This is the CORE Layer-1 correctness signal: forward outputs, the saved
logsumexp, and all three input gradients must match the reference to
tight tolerances across shapes, block sizes, and masking modes.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.attention import (
    _fwd, flash_attention, pick_block, vmem_bytes)

jax.config.update("jax_platform_name", "cpu")

ATOL = 2e-5
RTOL = 2e-5


def _rand(shape, seed):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.normal(size=shape).astype(np.float32))


def _qkv(b, h, s, d, seed=0):
    return (_rand((b, h, s, d), seed), _rand((b, h, s, d), seed + 1),
            _rand((b, h, s, d), seed + 2))


# ---------------------------------------------------------------- forward

@pytest.mark.parametrize("b,h,s,d", [
    (1, 1, 16, 8), (2, 3, 64, 32), (1, 2, 128, 64), (4, 1, 32, 16),
])
@pytest.mark.parametrize("causal", [True, False])
def test_forward_matches_ref(b, h, s, d, causal):
    q, k, v = _qkv(b, h, s, d)
    out = flash_attention(q, k, v, causal=causal)
    exp = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, exp, atol=ATOL, rtol=RTOL)


@pytest.mark.parametrize("block_q,block_k", [
    (8, 8), (16, 8), (8, 16), (32, 16), (16, 32), (64, 64),
])
def test_forward_block_shapes(block_q, block_k):
    q, k, v = _qkv(2, 2, 64, 32, seed=7)
    out = flash_attention(q, k, v, block_q=block_q, block_k=block_k)
    exp = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(out, exp, atol=ATOL, rtol=RTOL)


def test_forward_lse_matches_ref():
    q, k, v = _qkv(2, 2, 32, 16, seed=3)
    out, lse = _fwd(q, k, v, 1.0 / 4.0, True, 16, 16, True)
    exp_out, exp_lse = ref.mha_ref_lse(q, k, v)
    np.testing.assert_allclose(out, exp_out, atol=ATOL, rtol=RTOL)
    np.testing.assert_allclose(lse, exp_lse, atol=ATOL, rtol=RTOL)


def test_custom_scale():
    q, k, v = _qkv(1, 2, 32, 16, seed=9)
    out = flash_attention(q, k, v, scale=0.25)
    exp = ref.mha_ref(q, k, v, scale=0.25)
    np.testing.assert_allclose(out, exp, atol=ATOL, rtol=RTOL)


def test_first_row_attends_only_to_itself():
    # Causal row 0 must equal v[..., 0, :] exactly (softmax of one logit).
    q, k, v = _qkv(1, 1, 16, 8, seed=5)
    out = flash_attention(q, k, v, causal=True, block_q=8, block_k=8)
    np.testing.assert_allclose(out[..., 0, :], v[..., 0, :],
                               atol=1e-6, rtol=1e-6)


def test_invalid_block_size_raises():
    q, k, v = _qkv(1, 1, 24, 8)
    with pytest.raises(ValueError):
        flash_attention(q, k, v, block_q=16, block_k=16)


def test_under_jit():
    q, k, v = _qkv(1, 2, 32, 16, seed=11)
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v))
    np.testing.assert_allclose(f(q, k, v), ref.mha_ref(q, k, v),
                               atol=ATOL, rtol=RTOL)


# --------------------------------------------------------------- backward

@pytest.mark.parametrize("b,h,s,d", [
    (1, 1, 16, 8), (2, 2, 64, 32), (1, 2, 128, 32),
])
@pytest.mark.parametrize("causal", [True, False])
def test_grads_match_ref(b, h, s, d, causal):
    q, k, v = _qkv(b, h, s, d, seed=13)
    f = lambda q, k, v: jnp.sum(jnp.sin(flash_attention(q, k, v, causal=causal)))
    g = lambda q, k, v: jnp.sum(jnp.sin(ref.mha_ref(q, k, v, causal=causal)))
    got = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    exp = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for name, a, b_ in zip("dq dk dv".split(), got, exp):
        np.testing.assert_allclose(a, b_, atol=5e-5, rtol=5e-5,
                                   err_msg=name)


@pytest.mark.parametrize("block_q,block_k", [(8, 16), (16, 8), (32, 32)])
def test_grads_block_shapes(block_q, block_k):
    q, k, v = _qkv(1, 2, 64, 16, seed=17)
    f = lambda *a: jnp.sum(
        flash_attention(*a, block_q=block_q, block_k=block_k) ** 2)
    g = lambda *a: jnp.sum(ref.mha_ref(*a) ** 2)
    got = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    exp = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(got, exp):
        np.testing.assert_allclose(a, b_, atol=5e-5, rtol=5e-5)


def test_grad_under_jit_and_vjp_consistency():
    q, k, v = _qkv(1, 1, 32, 8, seed=19)
    do = _rand((1, 1, 32, 8), 23)
    _, vjp = jax.vjp(lambda q, k, v: flash_attention(q, k, v), q, k, v)
    _, ref_vjp = jax.vjp(lambda q, k, v: ref.mha_ref(q, k, v), q, k, v)
    for a, b_ in zip(vjp(do), ref_vjp(do)):
        np.testing.assert_allclose(a, b_, atol=5e-5, rtol=5e-5)


# ----------------------------------------------------------- hypothesis

@settings(max_examples=25, deadline=None)
@given(
    b=st.integers(1, 3),
    h=st.integers(1, 3),
    s_pow=st.integers(3, 7),   # seq in {8..128}
    d=st.sampled_from([4, 8, 16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2 ** 16),
)
def test_hypothesis_forward(b, h, s_pow, d, causal, seed):
    s = 2 ** s_pow
    q, k, v = _qkv(b, h, s, d, seed=seed)
    out = flash_attention(q, k, v, causal=causal)
    exp = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(out, exp, atol=5e-5, rtol=5e-5)


@settings(max_examples=10, deadline=None)
@given(
    s_pow=st.integers(3, 6),
    d=st.sampled_from([4, 8, 16]),
    bq_pow=st.integers(2, 5),
    bk_pow=st.integers(2, 5),
    seed=st.integers(0, 2 ** 16),
)
def test_hypothesis_grads(s_pow, d, bq_pow, bk_pow, seed):
    s = 2 ** s_pow
    bq, bk = min(2 ** bq_pow, s), min(2 ** bk_pow, s)
    q, k, v = _qkv(1, 2, s, d, seed=seed)
    f = lambda *a: jnp.sum(flash_attention(*a, block_q=bq, block_k=bk) ** 2)
    g = lambda *a: jnp.sum(ref.mha_ref(*a) ** 2)
    got = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    exp = jax.grad(g, argnums=(0, 1, 2))(q, k, v)
    for a, b_ in zip(got, exp):
        np.testing.assert_allclose(a, b_, atol=1e-4, rtol=1e-4)


# ------------------------------------------------------- shape discipline

def test_pick_block():
    assert pick_block(128) == 128
    assert pick_block(96) == 32
    assert pick_block(32) == 32
    assert pick_block(6) == 2
    assert pick_block(7) == 1


def test_vmem_budget_for_base_config():
    # base model: d_head = 64, seq = 128 -> default blocks 128.
    assert vmem_bytes(128, 128, 64) <= 16 * 1024 * 1024


def test_vmem_estimate_monotone_in_blocks():
    assert vmem_bytes(64, 64, 32) < vmem_bytes(128, 64, 32)
    assert vmem_bytes(64, 64, 32) < vmem_bytes(64, 128, 32)
