"""AOT lowering tests: the HLO-text artifacts and manifest that the Rust
runtime consumes must be well-formed and numerically faithful.
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.MODEL_SIZES["tiny"]
ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def _lower(fn, *specs):
    return jax.jit(fn).lower(*specs)


def test_hlo_text_has_entry_and_params():
    spec = jax.ShapeDtypeStruct((), jnp.int32)
    text = aot.to_hlo_text(_lower(lambda s: tuple(M.init_params(CFG, s)), spec))
    assert "ENTRY" in text
    assert "f32[" in text
    # return_tuple=True: root must be a tuple of all params
    assert f"({len(M.param_specs(CFG))} " in text.replace("\n", " ") or "tuple(" in text


def test_manifest_written(tmp_path):
    entry = aot.lower_size(CFG, M.AdamConfig(), str(tmp_path))
    assert set(entry["artifacts"]) == {"init", "fwd_bwd", "opt_step",
                                       "train_step"}
    for a in entry["artifacts"].values():
        assert (tmp_path / a["file"]).exists()
        assert (tmp_path / a["file"]).stat().st_size > 1000
    assert entry["config"]["param_count"] == M.param_count(CFG)
    assert len(entry["params"]) == len(M.param_specs(CFG))
    assert entry["tokens"]["shape"] == [CFG.batch, CFG.seq + 1]


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                    reason="run `make artifacts` first")
def test_checked_in_manifest_covers_tiny_and_small():
    with open(os.path.join(ART, "manifest.json")) as f:
        man = json.load(f)
    assert man["format"] == 1
    for size in ("tiny", "small"):
        assert size in man["models"], f"missing size {size}"
        entry = man["models"][size]
        for a in entry["artifacts"].values():
            path = os.path.join(ART, a["file"])
            assert os.path.exists(path), path


def test_lowered_matches_eager():
    """jit-compiled (what gets lowered) == eager for every artifact fn."""
    rng = np.random.default_rng(0)
    params = M.init_params(CFG, 0)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    tokens = jnp.asarray(rng.integers(
        0, CFG.vocab, size=(CFG.batch, CFG.seq + 1), dtype=np.int32))
    step = jnp.float32(1.0)
    opt = M.AdamConfig()

    def fwd_bwd_fn(params, tokens):
        loss, grads = M.fwd_bwd(CFG, list(params), tokens)
        return (loss, *grads)

    eager = fwd_bwd_fn(tuple(params), tokens)
    jitted = jax.jit(fwd_bwd_fn)(tuple(params), tokens)
    for a, b in zip(eager, jitted):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)

    def train_fn(p, m, v, s, t):
        loss, np_, nm, nv = M.train_step(CFG, opt, list(p), list(m),
                                         list(v), s, t)
        return (loss, *np_, *nm, *nv)

    eager = train_fn(params, m, v, step, tokens)
    jitted = jax.jit(train_fn)(params, m, v, step, tokens)
    for a, b in zip(eager, jitted):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)


def test_artifact_hlo_parses_parameter_counts():
    """fwd_bwd artifact must declare exactly n_params+1 parameters."""
    path = os.path.join(ART, "fwd_bwd_tiny.hlo.txt")
    if not os.path.exists(path):
        pytest.skip("run `make artifacts` first")
    with open(path) as f:
        text = f.read()
    entry = text[text.index("ENTRY"):]
    n_params = entry.count("parameter(")
    assert n_params == len(M.param_specs(CFG)) + 1  # params + tokens
