"""Layer-2 model tests: shapes, init, loss, Adam, and phase-split
consistency (fwd_bwd + opt_step must equal the fused train_step — this
is the invariant the Rust DP engine relies on when it inserts the
gradient-allreduce barrier between the two artifacts).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

jax.config.update("jax_platform_name", "cpu")

CFG = M.MODEL_SIZES["tiny"]
OPT = M.AdamConfig()


def _tokens(cfg, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(
        0, cfg.vocab, size=(cfg.batch, cfg.seq + 1), dtype=np.int32))


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, 0)


# ------------------------------------------------------------------ specs

def test_param_specs_order_is_stable():
    names = [n for n, _ in M.param_specs(CFG)]
    assert names[0] == "embed" and names[1] == "pos" and names[-1] == "ln_f"
    assert names.index("layer0.ln1") < names.index("layer0.wo")
    assert names.index("layer0.w2") < names.index("layer1.ln1")


def test_param_count_matches_specs(params):
    total = sum(int(np.prod(p.shape)) for p in params)
    assert total == M.param_count(CFG)


@pytest.mark.parametrize("size", list(M.MODEL_SIZES))
def test_all_sizes_have_valid_specs(size):
    cfg = M.MODEL_SIZES[size]
    specs = M.param_specs(cfg)
    assert len(specs) == 3 + 8 * cfg.n_layers
    assert cfg.d_model % cfg.n_heads == 0


def test_base_is_about_100m():
    assert 50e6 < M.param_count(M.MODEL_SIZES["base"]) < 150e6


# ------------------------------------------------------------------- init

def test_init_deterministic(params):
    again = M.init_params(CFG, 0)
    for a, b in zip(params, again):
        np.testing.assert_array_equal(a, b)


def test_init_seed_changes_weights(params):
    other = M.init_params(CFG, 1)
    diffs = [float(jnp.max(jnp.abs(a - b))) for a, b in zip(params, other)
             if a.ndim == 2]  # norms scales are all-ones for every seed
    assert max(diffs) > 0


def test_init_norm_scales_are_ones(params):
    for (name, _), p in zip(M.param_specs(CFG), params):
        if name.endswith(("ln1", "ln2", "ln_f")):
            np.testing.assert_array_equal(p, jnp.ones_like(p))


# ---------------------------------------------------------------- forward

def test_forward_shape(params):
    inputs = _tokens(CFG)[:, :-1]
    logits = M.forward(CFG, params, inputs)
    assert logits.shape == (CFG.batch, CFG.seq, CFG.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_loss_close_to_uniform_at_init(params):
    # Fresh init should be near ln(vocab) (uniform predictive entropy).
    loss = M.loss_fn(CFG, params, _tokens(CFG))
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5


def test_forward_is_causal(params):
    # Changing a future token must not change earlier logits.
    t = _tokens(CFG)
    inputs = t[:, :-1]
    logits_a = M.forward(CFG, params, inputs)
    mutated = inputs.at[:, -1].set((inputs[:, -1] + 1) % CFG.vocab)
    logits_b = M.forward(CFG, params, mutated)
    np.testing.assert_allclose(logits_a[:, :-1], logits_b[:, :-1],
                               atol=1e-5, rtol=1e-5)
    assert float(jnp.max(jnp.abs(logits_a[:, -1] - logits_b[:, -1]))) > 1e-4


# ---------------------------------------------------------------- fwd_bwd

def test_fwd_bwd_shapes(params):
    loss, grads = M.fwd_bwd(CFG, params, _tokens(CFG))
    assert loss.shape == ()
    assert len(grads) == len(params)
    for g, p in zip(grads, params):
        assert g.shape == p.shape
        assert bool(jnp.all(jnp.isfinite(g)))


def test_fwd_bwd_grad_nonzero(params):
    _, grads = M.fwd_bwd(CFG, params, _tokens(CFG))
    assert all(float(jnp.max(jnp.abs(g))) > 0 for g in grads)


# --------------------------------------------------------------- opt step

def test_adam_step_moves_params(params):
    loss0, grads = M.fwd_bwd(CFG, params, _tokens(CFG))
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    new_p, new_m, new_v = M.adam_step(CFG, OPT, params, m, v,
                                      jnp.float32(1.0), grads)
    assert any(float(jnp.max(jnp.abs(a - b))) > 0
               for a, b in zip(new_p, params))
    # first-step Adam with bias correction moves each param by ~lr
    deltas = [float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(new_p, params)]
    assert max(deltas) < 10 * OPT.lr


def test_training_reduces_loss_on_fixed_batch(params):
    tokens = _tokens(CFG, seed=42)
    p = params
    m = [jnp.zeros_like(x) for x in p]
    v = [jnp.zeros_like(x) for x in p]
    first = float(M.loss_fn(CFG, p, tokens))
    step = jax.jit(lambda p, m, v, s: M.train_step(CFG, OPT, p, m, v, s, tokens))
    for s in range(1, 21):
        loss, p, m, v = step(p, m, v, jnp.float32(s))
    assert float(loss) < first - 0.5


def test_grad_clip_bounds_update():
    opt = M.AdamConfig(grad_clip=1e-3)
    p = M.init_params(CFG, 0)
    loss, grads = M.fwd_bwd(CFG, p, _tokens(CFG))
    m = [jnp.zeros_like(x) for x in p]
    v = [jnp.zeros_like(x) for x in p]
    _, new_m, _ = M.adam_step(CFG, opt, p, m, v, jnp.float32(1.0), grads)
    gnorm = float(jnp.sqrt(sum(jnp.sum(jnp.square(g) / (1 - opt.beta1) ** 2)
                               for g in new_m)))
    assert gnorm <= 1e-3 * 1.01


# ------------------------------------------------- phase-split consistency

def test_split_equals_fused(params):
    """fwd_bwd + adam_step == train_step (the Rust barrier contract)."""
    tokens = _tokens(CFG, seed=7)
    m = [jnp.zeros_like(p) for p in params]
    v = [jnp.zeros_like(p) for p in params]
    step = jnp.float32(1.0)

    loss_a, grads = M.fwd_bwd(CFG, params, tokens)
    pa, ma, va = M.adam_step(CFG, OPT, params, m, v, step, grads)

    loss_b, pb, mb, vb = M.train_step(CFG, OPT, params, m, v, step, tokens)

    np.testing.assert_allclose(loss_a, loss_b, atol=1e-6, rtol=1e-6)
    for xs, ys in ((pa, pb), (ma, mb), (va, vb)):
        for x, y in zip(xs, ys):
            np.testing.assert_allclose(x, y, atol=1e-6, rtol=1e-6)


def test_dp_grad_average_equals_big_batch(params):
    """Averaging per-rank grads == grads of the concatenated batch.

    This is exactly what the Rust allreduce does between fwd_bwd and
    opt_step; loss is mean-reduced so equal-sized micro-batches average.
    """
    t1, t2 = _tokens(CFG, seed=1), _tokens(CFG, seed=2)
    _, g1 = M.fwd_bwd(CFG, params, t1)
    _, g2 = M.fwd_bwd(CFG, params, t2)
    avg = [(a + b) / 2 for a, b in zip(g1, g2)]

    big = jnp.concatenate([t1, t2], axis=0)
    cfg_big = M.ModelConfig("tiny2", CFG.n_layers, CFG.d_model, CFG.n_heads,
                            CFG.d_ff, CFG.vocab, CFG.seq, CFG.batch * 2)
    _, g_big = M.fwd_bwd(cfg_big, params, big)
    for a, b in zip(avg, g_big):
        np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)
